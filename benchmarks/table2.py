"""Paper Table 2: effectiveness/efficiency of every early-exit strategy
on three encoder-like corpora. Prints one block per encoder with
R*@1, R@100(->R@K), mRR@10, mean probes C, wall ms, speedup vs A-kNN95.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax.numpy as jnp

from benchmarks.common import K, TAU, ENCODERS, load_bench
from repro.core import metrics, policies, search
from repro.core.training import train_policy_models

# patience settings per encoder (tuned like the paper: larger delta for
# harder encoders)
DELTAS = {"star-like": 4, "contriever-like": 5, "tasb-like": 6}
PHI = 95.0
EXIT_W = 3.0


def run_encoder(name: str, *, quick: bool = False,
                smoke: bool = False) -> List[Dict]:
    b = load_bench(name, smoke=smoke)
    sp = b.splits
    n = b.n_probe
    q_test = jnp.asarray(b.corpus.queries[sp["test"]])
    exact = b.exact_ids[sp["test"]]
    relevant = b.corpus.relevant[sp["test"]]
    pm = train_policy_models(
        b.index, b.corpus.docs, b.corpus.queries[sp["train"]],
        b.corpus.queries[sp["valid"]], n_probe=n, k=K, tau=TAU,
        exit_weight=EXIT_W,
        n_trees=10 if smoke else (30 if quick else 80),
        max_depth=3 if smoke else 5)
    delta = DELTAS[name]
    pols = {
        f"A-kNN95(N={n})": policies.fixed(n, k=K, tau=TAU),
        "Reg": policies.regression(n, pm.reg, with_intersections=False,
                                   k=K, tau=TAU),
        "Reg+int": policies.regression(n, pm.reg_int,
                                       with_intersections=True, k=K,
                                       tau=TAU),
        f"Patience(d={delta})": policies.patience(n, delta, PHI, k=K,
                                                  tau=TAU),
        "Classifier": policies.classifier(n, pm.clf, k=K, tau=TAU),
        f"Classifier(w={EXIT_W:.0f})": policies.classifier(
            n, pm.clf_weighted, k=K, tau=TAU),
        "+Reg+int": policies.cascade_regression(
            n, pm.clf_weighted, pm.reg_int, k=K, tau=TAU),
        f"+Patience(d={delta})": policies.cascade_patience(
            n, pm.clf_weighted, delta, PHI, k=K, tau=TAU),
    }
    rows = []
    base_t = None
    for pname, pol in pols.items():
        res = search(b.index, q_test, pol)       # compile
        jnp.asarray(res.topk_ids).block_until_ready()
        t0 = time.time()
        reps = 1 if quick else 3
        for _ in range(reps):
            res = search(b.index, q_test, pol)
            res.topk_ids.block_until_ready()
        wall = (time.time() - t0) / reps * 1000
        ids = np.asarray(res.topk_ids)
        probes = np.asarray(res.probes)
        summ = metrics.summarize(ids, probes, exact, relevant, wall)
        if base_t is None:
            base_t = wall
        summ["Sp"] = base_t / wall
        summ["encoder"] = name
        summ["strategy"] = pname
        rows.append(summ)
    return rows


def main(quick: bool = False, smoke: bool = False) -> List[Dict]:
    all_rows = []
    encoders = ["star-like"] if smoke else list(ENCODERS)
    for enc in encoders:
        rows = run_encoder(enc, quick=quick, smoke=smoke)
        print(f"\n== {enc} (N={rows[0]['strategy']}) ==")
        hdr = f"{'strategy':22s} {'R*@1':>6s} {'R@K':>6s} {'mRR@10':>7s} " \
              f"{'C':>7s} {'T(ms)':>8s} {'Sp':>5s}"
        print(hdr)
        for r in rows:
            print(f"{r['strategy']:22s} {r['R*@1']:6.3f} {r['R@100']:6.3f} "
                  f"{r['mRR@10']:7.3f} {r['C']:7.1f} {r['T_ms']:8.1f} "
                  f"{r['Sp']:5.2f}")
        all_rows += rows
    return all_rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
