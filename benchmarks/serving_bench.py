"""Beyond-paper: wave-scheduler throughput (lane occupancy + effective
probes/query with and without compaction) — how per-query early exit
becomes batch throughput on a lockstep device (DESIGN §2) — plus the
live-mutation overlay cost: serving against a partially full delta
buffer, and a mixed query/mutation stream with background merges
(``repro.index``).  The live rows report recall against the static
exact oracle; the stream row's ``recall_gap`` is the acceptance signal
(must stay within 0.01 of the static run).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import K, load_bench
from repro.core import metrics
from repro.core.serving import WaveScheduler
from repro.index import DeltaFull, IndexRegistry, LiveIndex, version_of


def _run(ws, qs, *, compact=True, on_wave=None, exact=None) -> Dict:
    n = qs.shape[0]
    t0 = time.time()
    rep = ws.serve(qs, compact=compact, on_wave=on_wave)
    wall = time.time() - t0
    probes = np.array([rep.probes[i] for i in range(n)])
    row = {"occupancy": rep.occupancy, "waves": rep.waves,
           "lane_steps": rep.lane_steps,
           "lane_steps_per_query": rep.lane_steps / n,
           "mean_probes": float(probes.mean()), "wall_s": wall}
    if exact is not None:
        ids = np.stack([rep.results[i] for i in range(n)])
        row["recall"] = metrics.r_star_at_k(ids, exact[:n])
    return row


def _corpus_like(rng, docs, m):
    src = rng.integers(0, len(docs), m)
    return (docs[src] + rng.normal(scale=0.05, size=(m, docs.shape[1]))
            ).astype(np.float32)


def main(encoder: str = "star-like", n_queries: int = 512,
         smoke: bool = False) -> Dict:
    b = load_bench(encoder, smoke=smoke)
    if smoke:
        n_queries = min(n_queries, 128)
    qs = b.corpus.queries[:n_queries]
    exact = b.exact_ids
    out = {}

    def ws(registry=None, fused=True):
        return WaveScheduler(b.index, wave_size=64, chunk=4, k=K,
                             n_probe=b.n_probe, delta=4, phi=95.0,
                             use_fused=fused, registry=registry)

    # rows: compaction off/on with the unfused gather+einsum advance,
    # then compaction on with the fused scan+merge kernel dispatch
    cases = [("baseline", False, False), ("compact", True, False),
             ("fused", True, True)]
    for tag, compact, fused in cases:
        out[tag] = _run(ws(fused=fused), qs, compact=compact, exact=exact)
        r = out[tag]
        print(f"{tag:14s} occ={r['occupancy']:.2f} waves={r['waves']:4d} "
              f"lane_steps/q={r['lane_steps_per_query']:6.1f} "
              f"C={r['mean_probes']:5.1f} R*@k={r['recall']:.3f} "
              f"wall={r['wall_s']:.1f}s")

    # delta-buffer occupancy sweep: how much does brute-force scanning
    # a fuller buffer cost, and does the overlay keep recall?
    cap = 256 if smoke else 512
    for frac in ([0.5] if smoke else [0.25, 0.5, 1.0]):
        live = LiveIndex(b.index, delta_cap=cap)
        rng = np.random.default_rng(17)
        live.add(_corpus_like(rng, b.corpus.docs, int(frac * cap)))
        reg = IndexRegistry(version_of(live))
        tag = f"delta_occ_{frac:.2f}"
        out[tag] = _run(ws(registry=reg), qs, exact=exact)
        out[tag]["delta_occupancy"] = live.delta.occupancy()
        r = out[tag]
        print(f"{tag:14s} occ={r['occupancy']:.2f} "
              f"C={r['mean_probes']:5.1f} R*@k={r['recall']:.3f} "
              f"wall={r['wall_s']:.1f}s")

    # mixed query/mutation stream: adds+deletes per wave, background
    # merge_delta every few waves, atomic version swaps mid-stream
    live = LiveIndex(b.index, delta_cap=cap)
    reg = IndexRegistry(version_of(live))
    rng = np.random.default_rng(23)
    added: list = []
    stats = {"adds": 0, "deletes": 0, "merges": 0}
    rate = 4 if smoke else 8

    def mutate(wave: int) -> None:
        try:
            added.extend(int(i)
                         for i in live.add(_corpus_like(rng, b.corpus.docs,
                                                        rate)))
            stats["adds"] += rate
        except DeltaFull:
            live.merge_delta()
            stats["merges"] += 1
        if len(added) > rate:
            live.delete([added.pop(rng.integers(len(added)))
                         for _ in range(rate // 4)])
            stats["deletes"] += rate // 4
        if wave % 8 == 0 and len(live.delta):
            live.merge_delta()
            stats["merges"] += 1
        reg.publish(version_of(live))

    row = _run(ws(registry=reg), qs, on_wave=mutate, exact=exact)
    row.update(stats)
    row["versions"] = live.version
    row["swaps"] = reg.swaps
    row["recall_static"] = out["fused"]["recall"]
    row["recall_gap"] = abs(row["recall"] - out["fused"]["recall"])
    out["live_stream"] = row
    print(f"{'live_stream':14s} adds={stats['adds']} "
          f"dels={stats['deletes']} merges={stats['merges']} "
          f"R*@k={row['recall']:.3f} gap={row['recall_gap']:.4f} "
          f"wall={row['wall_s']:.1f}s")

    sp = out["baseline"]["lane_steps"] / out["compact"]["lane_steps"]
    print(f"compaction device-time speedup: {sp:.2f}x")
    same = out["fused"]["mean_probes"] == out["compact"]["mean_probes"]
    print(f"fused advance mean probes match: {same}")
    out["speedup"] = sp
    return out


if __name__ == "__main__":
    main()
