"""Beyond-paper: wave-scheduler throughput (lane occupancy + effective
probes/query with and without compaction) — how per-query early exit
becomes batch throughput on a lockstep device (DESIGN §2)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import K, load_bench
from repro.core.serving import WaveScheduler


def main(encoder: str = "star-like", n_queries: int = 512) -> Dict:
    b = load_bench(encoder)
    qs = b.corpus.queries[:n_queries]
    out = {}
    # rows: compaction off/on with the unfused gather+einsum advance,
    # then compaction on with the fused scan+merge kernel dispatch
    cases = [("baseline", False, False), ("compact", True, False),
             ("fused", True, True)]
    for tag, compact, fused in cases:
        ws = WaveScheduler(b.index, wave_size=64, chunk=4, k=K,
                           n_probe=b.n_probe, delta=4, phi=95.0,
                           use_fused=fused)
        t0 = time.time()
        rep = ws.serve(qs, compact=compact)
        wall = time.time() - t0
        probes = np.array([rep.probes[i] for i in range(n_queries)])
        out[tag] = {"occupancy": rep.occupancy, "waves": rep.waves,
                    "lane_steps": rep.lane_steps,
                    "lane_steps_per_query": rep.lane_steps / n_queries,
                    "mean_probes": float(probes.mean()),
                    "wall_s": wall}
        print(f"{tag:9s} occ={rep.occupancy:.2f} waves={rep.waves:4d} "
              f"lane_steps/q={rep.lane_steps / n_queries:6.1f} "
              f"C={probes.mean():5.1f} wall={wall:.1f}s")
    sp = out["baseline"]["lane_steps"] / out["compact"]["lane_steps"]
    print(f"compaction device-time speedup: {sp:.2f}x")
    same = out["fused"]["mean_probes"] == out["compact"]["mean_probes"]
    print(f"fused advance mean probes match: {same}")
    out["speedup"] = sp
    return out


if __name__ == "__main__":
    main()
