"""Shared benchmark substrate: three synthetic 'encoders' standing in
for STAR / Contriever / TAS-B (DESIGN §6). Harder encoders (larger
spread) need larger N for R*@1 >= 0.95, mirroring the paper's
N = 80 / 140 / 190 progression. Corpora and indexes are cached on disk.
"""
from __future__ import annotations

import os
import pickle
import time
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import build_index, brute_force
from repro.core.ivf import IVFIndex
from repro.core.training import choose_n_probe
from repro.data.synthetic import Corpus, clustered_corpus

CACHE = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "bench_cache")

# name -> (spread, hard_frac): harder encoder == more dispersed clusters
ENCODERS = {
    "star-like": (0.22, 0.25),
    "contriever-like": (0.32, 0.35),
    "tasb-like": (0.40, 0.45),
}

N_DOCS = 60_000
DIM = 64
N_COMPONENTS = 512
N_QUERIES = 3072
K = 50
TAU = 5
RHO = 0.95


@dataclass
class Bench:
    name: str
    corpus: Corpus
    index: IVFIndex
    n_probe: int
    exact_ids: np.ndarray      # (nq, K)
    splits: Dict[str, slice]


def load_bench(name: str, *, force: bool = False) -> Bench:
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"{name}.pkl")
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            saved = pickle.load(f)
        corpus = Corpus(saved["docs"], saved["queries"], saved["relevant"])
        index = build_index(corpus.docs, N_COMPONENTS, list_pad=256,
                            n_iters=6, seed=0)
        return Bench(name, corpus, index, saved["n_probe"],
                     saved["exact_ids"], _splits())
    spread, hard = ENCODERS[name]
    seed = abs(hash(name)) % 2 ** 31
    corpus = clustered_corpus(n_docs=N_DOCS, dim=DIM,
                              n_components=N_COMPONENTS,
                              n_queries=N_QUERIES, spread=spread,
                              hard_frac=hard, seed=seed)
    index = build_index(corpus.docs, N_COMPONENTS, list_pad=256,
                        n_iters=6, seed=0)
    sp = _splits()
    n_probe = choose_n_probe(index, corpus.docs,
                             corpus.queries[sp["valid"]], rho=RHO, k=K,
                             n_max=N_COMPONENTS)
    exact = np.empty((N_QUERIES, K), np.int32)
    for s in range(0, N_QUERIES, 512):
        _, ids = brute_force(jnp.asarray(corpus.docs),
                             jnp.asarray(corpus.queries[s: s + 512]), K)
        exact[s: s + 512] = np.asarray(ids)
    with open(path, "wb") as f:
        pickle.dump({"docs": corpus.docs, "queries": corpus.queries,
                     "relevant": corpus.relevant, "n_probe": n_probe,
                     "exact_ids": exact}, f)
    return Bench(name, corpus, index, n_probe, exact, sp)


def _splits() -> Dict[str, slice]:
    n_test = 1024
    n_valid = 512
    return {"train": slice(0, N_QUERIES - n_test - n_valid),
            "valid": slice(N_QUERIES - n_test - n_valid,
                           N_QUERIES - n_test),
            "test": slice(N_QUERIES - n_test, N_QUERIES)}
