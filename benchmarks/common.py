"""Shared benchmark substrate: three synthetic 'encoders' standing in
for STAR / Contriever / TAS-B (DESIGN §6). Harder encoders (larger
spread) need larger N for R*@1 >= 0.95, mirroring the paper's
N = 80 / 140 / 190 progression. Corpora and indexes are cached on disk.
"""
from __future__ import annotations

import os
import pickle
import time
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import build_index, brute_force
from repro.core.ivf import IVFIndex
from repro.core.training import choose_n_probe
from repro.data.synthetic import Corpus, clustered_corpus

CACHE = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "bench_cache")

# name -> (spread, hard_frac): harder encoder == more dispersed clusters
ENCODERS = {
    "star-like": (0.22, 0.25),
    "contriever-like": (0.32, 0.35),
    "tasb-like": (0.40, 0.45),
}

N_DOCS = 60_000
DIM = 64
N_COMPONENTS = 512
N_QUERIES = 3072
K = 50
TAU = 5
RHO = 0.95


# smoke mode: a few-seconds substrate for `make bench-smoke` / CI —
# same pipeline shape, fraction of the data
SMOKE_N_DOCS = 4000
SMOKE_DIM = 24
SMOKE_N_COMPONENTS = 64
SMOKE_N_QUERIES = 384


@dataclass
class Bench:
    name: str
    corpus: Corpus
    index: IVFIndex
    n_probe: int
    exact_ids: np.ndarray      # (nq, K)
    splits: Dict[str, slice]


def _sizes(smoke: bool) -> Tuple[int, int, int, int]:
    if smoke:
        return SMOKE_N_DOCS, SMOKE_DIM, SMOKE_N_COMPONENTS, SMOKE_N_QUERIES
    return N_DOCS, DIM, N_COMPONENTS, N_QUERIES


def load_bench(name: str, *, force: bool = False,
               smoke: bool = False) -> Bench:
    n_docs, dim, comps, nq = _sizes(smoke)
    os.makedirs(CACHE, exist_ok=True)
    fname = f"{name}_smoke.pkl" if smoke else f"{name}.pkl"
    path = os.path.join(CACHE, fname)
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            saved = pickle.load(f)
        corpus = Corpus(saved["docs"], saved["queries"], saved["relevant"])
        index = build_index(corpus.docs, comps, list_pad=256,
                            n_iters=6, seed=0)
        return Bench(name, corpus, index, saved["n_probe"],
                     saved["exact_ids"], _splits(nq, smoke))
    spread, hard = ENCODERS[name]
    seed = abs(hash(name)) % 2 ** 31
    corpus = clustered_corpus(n_docs=n_docs, dim=dim,
                              n_components=comps,
                              n_queries=nq, spread=spread,
                              hard_frac=hard, seed=seed)
    index = build_index(corpus.docs, comps, list_pad=256,
                        n_iters=6, seed=0)
    sp = _splits(nq, smoke)
    n_probe = choose_n_probe(index, corpus.docs,
                             corpus.queries[sp["valid"]], rho=RHO, k=K,
                             n_max=comps)
    exact = np.empty((nq, K), np.int32)
    for s in range(0, nq, 512):
        _, ids = brute_force(jnp.asarray(corpus.docs),
                             jnp.asarray(corpus.queries[s: s + 512]), K)
        exact[s: s + 512] = np.asarray(ids)
    with open(path, "wb") as f:
        pickle.dump({"docs": corpus.docs, "queries": corpus.queries,
                     "relevant": corpus.relevant, "n_probe": n_probe,
                     "exact_ids": exact}, f)
    return Bench(name, corpus, index, n_probe, exact, sp)


def _splits(nq: int = N_QUERIES, smoke: bool = False) -> Dict[str, slice]:
    n_test = 128 if smoke else 1024
    n_valid = 64 if smoke else 512
    return {"train": slice(0, nq - n_test - n_valid),
            "valid": slice(nq - n_test - n_valid, nq - n_test),
            "test": slice(nq - n_test, nq)}
