"""§Perf hillclimb driver: one experiment per hypothesis, each printing
baseline vs candidate roofline terms (full log in EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb qwen_remat
    PYTHONPATH=src python -m benchmarks.hillclimb ivf_width
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes=all-reduce-promotion")
import dataclasses
import json
import sys

import jax

from repro.launch import dryrun as dr


def _run(arch, shape, out_tag, cfg_override=None):
    """run_cell with an optional model-config override."""
    from repro.configs import base as cb
    spec = cb.get_arch(arch)
    if cfg_override:
        new = dataclasses.replace(spec.model, **cfg_override)
        patched = cb.ArchSpec(spec.arch_id, spec.family, new, spec.shapes,
                              spec.source)
        cb._REGISTRY[arch] = patched
    try:
        rec = dr.run_cell(arch, shape, False, f"artifacts/hillclimb/{out_tag}")
    finally:
        cb._REGISTRY[arch] = spec
    t = {}
    if rec.get("cost"):
        from repro.launch.hlo_analysis import roofline_terms
        terms = roofline_terms(rec["cost"]["flops"], rec["cost"]["bytes"],
                               rec["collectives"]["total_bytes"])
        t = {k: round(v * 1e3, 2) for k, v in terms.items()
             if k.endswith("_s")}
    print(f"[{out_tag}] {rec['status']} peak="
          f"{rec.get('memory', {}).get('peak_gb', float('nan')):.2f}GB "
          f"terms(ms)={t}")
    return rec


def qwen_bf16():
    print("HYPOTHESIS: bf16 stored params halve every FSDP all-gather "
          "(collective term ~ -40%) and cut HBM bytes; fp32 precision "
          "lives in the AdamW moments.")
    _run("qwen1.5-32b", "train_4k", "qwen_base")
    _run("qwen1.5-32b", "train_4k", "qwen_bf16",
         {"param_dtype": "bfloat16"})


def qwen_chunk():
    print("HYPOTHESIS: the (chunk,S) attention scan reshards per chunk; "
          "4x larger chunks cut the per-chunk collective count 4x at "
          "4x score-tile memory.")
    _run("qwen1.5-32b", "train_4k", "qwen_chunk512")
    _run("qwen1.5-32b", "train_4k", "qwen_chunk2048",
         {"attn_chunk": 2048})


def qwen_nomicro():
    print("HYPOTHESIS: microbatching (m=4) repeats weight gathers 4x; "
          "single-batch variant trades activation memory for fewer "
          "collectives.")
    _run("qwen1.5-32b", "train_4k", "qwen_m4")
    import repro.launch.cells as cells
    orig = cells._microbatches
    cells._microbatches = lambda *a: 1
    try:
        _run("qwen1.5-32b", "train_4k", "qwen_m1")
    finally:
        cells._microbatches = orig


def qwen_remat():
    print("HYPOTHESIS: dots_saveable remat keeps matmul outputs, removing "
          "the backward re-all-gathers of the seq-parallel stream "
          "(collective term down) at the cost of HBM.")
    _run("qwen1.5-32b", "train_4k", "qwen_base")
    _run("qwen1.5-32b", "train_4k", "qwen_dots",
         {"remat_policy": "dots"})


def ivf_width():
    print("HYPOTHESIS: probing w clusters per loop step amortises the "
          "merge/all-gather/top-k per step (overhead/w); true scan "
          "bytes unchanged.")
    _run("msmarco-ivf", "ivf_serve_1k", "ivf_f32w1",
         {"storage_dtype": "float32", "probe_width": 1})
    _run("msmarco-ivf", "ivf_serve_1k", "ivf_bf16w1",
         {"storage_dtype": "bfloat16", "probe_width": 1})
    _run("msmarco-ivf", "ivf_serve_1k", "ivf_bf16w4",
         {"storage_dtype": "bfloat16", "probe_width": 4})
    _run("msmarco-ivf", "ivf_serve_1k", "ivf_int8w4",
         {"storage_dtype": "int8", "probe_width": 4})


def moe_a2a():
    print("HYPOTHESIS: manual all-to-all MoE dispatch (tokens sharded "
          "over model inside the body) removes the model-axis "
          "replication all-gathers that dominate dbrx train.")
    _run("dbrx-132b", "train_4k", "dbrx_base")
    _run("dbrx-132b", "train_4k", "dbrx_a2a",
         {"moe": dataclasses.replace(
             cbmodel("dbrx-132b").moe, a2a_dispatch=True)})


def cbmodel(arch):
    from repro.configs import base as cb
    return cb.get_arch(arch).model


if __name__ == "__main__":
    globals()[sys.argv[1]]()
