"""Paper Figure 1: phi_h saturation, overall and split by Exit/Continue
label at tau. Prints an ASCII table of mean/p5/p95 per probe rank."""
from __future__ import annotations

from typing import Dict

import numpy as np

import jax.numpy as jnp

from benchmarks.common import K, TAU, load_bench
from repro.core import brute_force, min_probes_labels, probe_trace


def main(encoder: str = "star-like", n_plot: int = 40) -> Dict:
    b = load_bench(encoder)
    q = jnp.asarray(b.corpus.queries[:1024])
    traj, phi = probe_trace(b.index, q, n_plot, K)    # phi: (N-1, B)
    exact1 = b.exact_ids[:1024, 0]
    labels = min_probes_labels(traj, exact1, n_plot)
    exit_m = labels <= TAU
    print(f"phi_h saturation ({encoder}); Exit fraction at tau={TAU}: "
          f"{exit_m.mean():.2f}")
    print(f"{'h':>3s} {'mean':>6s} {'p5':>6s} {'p95':>6s} "
          f"{'Exit':>6s} {'Cont':>6s}")
    out = {"h": [], "mean": [], "exit": [], "cont": []}
    for h in range(1, phi.shape[0] + 1, max(1, phi.shape[0] // 20)):
        row = phi[h - 1]
        out["h"].append(h + 1)
        out["mean"].append(float(row.mean()))
        out["exit"].append(float(row[exit_m].mean()))
        out["cont"].append(float(row[~exit_m].mean()))
        print(f"{h + 1:3d} {row.mean():6.1f} "
              f"{np.percentile(row, 5):6.1f} "
              f"{np.percentile(row, 95):6.1f} "
              f"{row[exit_m].mean():6.1f} {row[~exit_m].mean():6.1f}")
    # the paper's two claims:
    assert out["mean"][-1] > out["mean"][0], "phi must climb"
    gaps = [e - c for e, c in zip(out["exit"][:6], out["cont"][:6])]
    print(f"early-probe Exit-Continue separation: "
          f"{np.mean(gaps):.1f} pts")
    return out


if __name__ == "__main__":
    main()
