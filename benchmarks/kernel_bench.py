"""Kernel micro-benchmarks: correctness vs oracle + XLA-path timing.

CPU interpret-mode timings of the Pallas bodies are not meaningful
hardware numbers; what we measure here is (a) allclose vs the ref and
(b) the jnp/XLA path wall time as the CPU baseline the TPU kernels
replace. Printed as name,us_per_call,max_err CSV.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def main() -> List[Dict]:
    rows = []
    r = jax.random
    # flash attention
    q = r.normal(r.PRNGKey(0), (4, 512, 64))
    k = r.normal(r.PRNGKey(1), (4, 512, 64))
    v = r.normal(r.PRNGKey(2), (4, 512, 64))
    jref = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    err = float(jnp.max(jnp.abs(
        ops.flash_attention(q, k, v, blk_q=128, blk_k=128)
        - jref(q, k, v))))
    rows.append({"name": "flash_attention_ref_xla",
                 "us": _time(jref, q, k, v), "err": err})
    # ivf scan
    docs = r.normal(r.PRNGKey(3), (65536, 64))
    qs = r.normal(r.PRNGKey(4), (64, 64))
    offs = jnp.arange(64, dtype=jnp.int32) * 256
    szs = jnp.full((64,), 250, jnp.int32)
    jscan = jax.jit(lambda a, b, c, d: ref.ivf_scan_ref(a, b, c, d, 256))
    err = float(jnp.max(jnp.abs(jnp.nan_to_num(
        ops.ivf_scan(qs, docs, offs, szs, list_pad=256)
        - jscan(qs, docs, offs, szs), neginf=0.0))))
    rows.append({"name": "ivf_scan_ref_xla",
                 "us": _time(jscan, qs, docs, offs, szs), "err": err})
    # topk merge
    s = r.normal(r.PRNGKey(5), (256, 50))
    i = r.randint(r.PRNGKey(6), (256, 50), 0, 10 ** 6)
    ns = r.normal(r.PRNGKey(7), (256, 256))
    ni = r.randint(r.PRNGKey(8), (256, 256), 0, 10 ** 6)
    jmerge = jax.jit(lambda a, b, c, d: ref.topk_merge_ref(a, b, c, d, 50))
    o1 = ops.topk_merge(s, i, ns, ni, 50)
    o2 = jmerge(s, i, ns, ni)
    err = float(jnp.max(jnp.abs(o1[0] - o2[0])))
    rows.append({"name": "topk_merge_ref_xla",
                 "us": _time(jmerge, s, i, ns, ni), "err": err})
    # embedding bag
    table = r.normal(r.PRNGKey(9), (100_000, 16))
    ids = r.randint(r.PRNGKey(10), (1024, 26), 0, 100_000)
    jbag = jax.jit(ref.embedding_bag_ref)
    err = float(jnp.max(jnp.abs(ops.embedding_bag(table, ids)
                                - jbag(table, ids))))
    rows.append({"name": "embedding_bag_ref_xla",
                 "us": _time(jbag, table, ids), "err": err})
    for row in rows:
        print(f"{row['name']},{row['us']:.1f},{row['err']:.2e}")
    return rows


if __name__ == "__main__":
    main()
