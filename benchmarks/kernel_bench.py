"""Kernel micro-benchmarks: correctness vs oracle + timing of both the
jnp/XLA ref path and the ``ops.*`` dispatch path.

CPU interpret-mode timings of the Pallas bodies are not meaningful
hardware numbers; what we measure here is (a) allclose vs the ref and
(b) wall time of each path on this backend — the ``*_ref_xla`` rows are
the CPU baseline the TPU kernels replace, the ``*_ops`` rows catch
dispatch-path regressions. Printed as name,us_per_call,max_err CSV.

``main`` returns the BENCH_kernels.json artifact: the legacy ``rows``
plus a ``fused_sweep`` (chunk × blk_l, pipelined/unpipelined, with and
without the in-kernel delta stream), a ``sort`` section timing the
packed (score,id) network against the legacy three-lane tagged
network, and backend metadata.  ``pltpu.emit_pipeline`` asserts a real
TPU at trace time, so on CPU the pipelined variants are recorded as
pending (``us: null``) — the speedup claim is documented as pending a
TPU run, not measured in interpret mode.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref, sort


def _time(fn, *args, reps: int = 5) -> float:
    jax.block_until_ready(fn(*args))        # single warmup / compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def _err(a, b) -> float:
    """max abs err with -inf/-inf treated as equal."""
    return float(jnp.max(jnp.abs(jnp.nan_to_num(
        jnp.asarray(a) - jnp.asarray(b), neginf=0.0, posinf=0.0))))


def _bitonic_desc_tagged_legacy(s, i, t):
    """The fused kernel's pre-packed three-lane sort (score f32, id
    i32, tag i32 — three shuffles + three selects per pass), kept here
    ONLY as the packed-vs-tagged benchmark baseline."""
    r, m = s.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    stages = int(np.log2(m))

    def partner(x, jj):
        x3 = x.reshape(r, m // (2 * jj), 2, jj)
        return jnp.flip(x3, axis=2).reshape(r, m)

    for stage in range(1, stages + 1):
        kk = 1 << stage
        for jj in (1 << p for p in range(stage - 1, -1, -1)):
            keep_max = jnp.where((idx & kk) == 0,
                                 (idx & jj) == 0,
                                 (idx & jj) != 0)
            ps, pi, pt = partner(s, jj), partner(i, jj), partner(t, jj)
            take_p = jnp.where(keep_max, ps > s, ps < s)
            s = jnp.where(take_p, ps, s)
            i = jnp.where(take_p, pi, i)
            t = jnp.where(take_p, pt, t)
    return s, i, t


def _sort_section(reps: int, smoke: bool) -> Dict:
    """Packed (2-word record) vs legacy tagged (3-lane) network."""
    rng = np.random.default_rng(17)
    r, m = (64, 512) if not smoke else (16, 512)
    sc = jnp.asarray(rng.normal(size=(r, m)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 1 << 29, (r, m)).astype(np.int32))
    tags = jnp.zeros((r, m), jnp.int32)

    packed = jax.jit(lambda s, i: sort.bitonic_desc_packed(
        sort.pack(sort.score_to_key(s), i)))
    tagged = jax.jit(_bitonic_desc_tagged_legacy)
    out_p = packed(sc, ids)
    out_t = tagged(sc, ids, tags)
    np.testing.assert_array_equal(
        np.asarray(sort.key_to_score(out_p[:, 0])), np.asarray(out_t[0]))
    packed_us = _time(packed, sc, ids, reps=reps)
    tagged_us = _time(tagged, sc, ids, tags, reps=reps)
    return {"rows": r, "m": m, "packed_us": packed_us,
            "tagged_us": tagged_us,
            "speedup": tagged_us / max(packed_us, 1e-9)}


def main(smoke: bool = False) -> Dict:
    rows = []
    reps = 2 if smoke else 5

    def add(name, us, err):
        rows.append({"name": name, "us": us, "err": err})

    r = jax.random
    # flash attention
    q = r.normal(r.PRNGKey(0), (4, 512, 64))
    k = r.normal(r.PRNGKey(1), (4, 512, 64))
    v = r.normal(r.PRNGKey(2), (4, 512, 64))
    jref = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    fa_ops = ops.flash_attention(q, k, v, blk_q=128, blk_k=128)
    err = _err(fa_ops, jref(q, k, v))
    add("flash_attention_ref_xla", _time(jref, q, k, v, reps=reps), err)
    add("flash_attention_ops",
        _time(lambda: ops.flash_attention(q, k, v, blk_q=128, blk_k=128), reps=reps),
        err)

    # ivf scan
    docs = r.normal(r.PRNGKey(3), (65536, 64))
    qs = r.normal(r.PRNGKey(4), (64, 64))
    offs = jnp.arange(64, dtype=jnp.int32) * 256
    szs = jnp.full((64,), 250, jnp.int32)
    jscan = jax.jit(lambda a, b, c, d: ref.ivf_scan_ref(a, b, c, d, 256))
    err = _err(ops.ivf_scan(qs, docs, offs, szs, list_pad=256),
               jscan(qs, docs, offs, szs))
    add("ivf_scan_ref_xla", _time(jscan, qs, docs, offs, szs, reps=reps), err)
    add("ivf_scan_ops",
        _time(lambda: ops.ivf_scan(qs, docs, offs, szs, list_pad=256), reps=reps), err)

    # topk merge
    s = r.normal(r.PRNGKey(5), (256, 50))
    i = r.randint(r.PRNGKey(6), (256, 50), 0, 10 ** 6)
    ns = r.normal(r.PRNGKey(7), (256, 256))
    ni = r.randint(r.PRNGKey(8), (256, 256), 0, 10 ** 6)
    jmerge = jax.jit(lambda a, b, c, d: ref.topk_merge_ref(a, b, c, d, 50))
    err = _err(ops.topk_merge(s, i, ns, ni, 50)[0],
               jmerge(s, i, ns, ni)[0])
    add("topk_merge_ref_xla", _time(jmerge, s, i, ns, ni, reps=reps), err)
    add("topk_merge_ops",
        _time(lambda: ops.topk_merge(s, i, ns, ni, 50), reps=reps), err)

    # fused multi-probe scan -> merge: chunk sweep (total probes per
    # query fixed at 8, so rows compare dispatch granularity — how many
    # probes amortise one kernel launch — not total work)
    B, n_pr, lp, kk = 16, 8, 256, 50
    fdocs = r.normal(r.PRNGKey(11), (B * n_pr * lp, 64))
    fids = jnp.arange(B * n_pr * lp, dtype=jnp.int32)
    all_offs = (jnp.arange(B * n_pr, dtype=jnp.int32) * lp).reshape(B, n_pr)
    fq = r.normal(r.PRNGKey(12), (B, 64))
    rs = jnp.full((B, kk), -jnp.inf, jnp.float32)
    ri = jnp.full((B, kk), -1, jnp.int32)
    chunk4 = all_offs[:, :4]
    fszs4 = jnp.full((B, 4), lp - 6, jnp.int32)
    jfused = jax.jit(lambda: ref.ivf_scan_merge_ref(
        fq, fdocs, fids, chunk4, fszs4, rs, ri, kk, lp))
    o_ops = ops.ivf_scan_merge(fq, fdocs, fids, chunk4, fszs4, rs, ri,
                               k=kk, list_pad=lp, chunk=4)
    o_ref = jfused()
    err = max(_err(o_ops[0], o_ref[0]),
              float(jnp.max(jnp.abs(o_ops[2] - o_ref[2]))))
    add("ivf_scan_merge_ref_xla", _time(jfused, reps=reps), err)

    def sweep_chunk(chunk: int, blk_l: int = 64) -> float:
        """us for the full n_pr probes issued as n_pr/chunk dispatches."""
        offs = all_offs.reshape(B, n_pr // chunk, chunk)
        szs = jnp.full((B, chunk), lp - 6, jnp.int32)

        def run():
            s, i = rs, ri
            for j in range(n_pr // chunk):
                snap_s, snap_i, _ = ops.ivf_scan_merge(
                    fq, fdocs, fids, offs[:, j], szs, s, i,
                    k=kk, list_pad=lp, chunk=chunk, blk_l=blk_l)
                s, i = snap_s[:, -1], snap_i[:, -1]
            return s, i

        return _time(run, reps=reps)

    for chunk in ([4] if smoke else [1, 2, 4, 8]):
        add(f"ivf_scan_merge_ops_c{chunk}", sweep_chunk(chunk), err)

    # chunk × blk_l sweep: dispatch granularity vs tile height.  The
    # ops wrapper picks the tile streaming mode per backend: pipelined
    # (double-buffered emit_pipeline) on TPU, the unrolled interpret
    # fallback on CPU — so the pipelined variant is only measurable on
    # real hardware and is recorded as pending elsewhere.
    on_tpu = jax.default_backend() == "tpu"
    fused_sweep = []
    for chunk in ([4] if smoke else [2, 4, 8]):
        for blk_l in ([64] if smoke else [64, 128, 256]):
            fused_sweep.append({
                "chunk": chunk, "blk_l": blk_l,
                "pipelined": on_tpu, "delta": False,
                "us": sweep_chunk(chunk, blk_l), "err": err})

    # in-kernel delta stream: same probes plus a 256-entry buffer
    # (second prefetch stream + per-slot gated merge, one dispatch)
    dcap = 256
    dl_vecs = r.normal(r.PRNGKey(14), (dcap, 64))
    dl_ids = jnp.arange(dcap, dtype=jnp.int32) + 10 ** 7
    dl_assign = jnp.zeros((dcap,), jnp.int32)     # never probed here
    szs4 = jnp.full((B, 4), lp - 6, jnp.int32)
    gates = jnp.full((B, 4), -2, jnp.int32)

    def run_delta():
        return ops.ivf_scan_merge(
            fq, fdocs, fids, all_offs[:, :4], szs4, rs, ri,
            dl_vecs, dl_ids, dl_assign, gates,
            k=kk, list_pad=lp, chunk=4)

    fused_sweep.append({
        "chunk": 4, "blk_l": 64, "pipelined": on_tpu, "delta": True,
        "us": _time(run_delta, reps=reps), "err": err})
    for row in fused_sweep:
        mode = "pipelined" if row["pipelined"] else "unpipelined"
        tag = "_delta" if row["delta"] else ""
        add(f"fused_{mode}_c{row['chunk']}_blk{row['blk_l']}{tag}",
            row["us"], row["err"])
    if not on_tpu:
        # emit_pipeline cannot trace off-TPU: document, don't fake
        add("fused_pipelined_c4_blk64", None, None)

    # delta scan (live-mutation buffer brute force)
    dvecs = r.normal(r.PRNGKey(13), (1024, 64))
    dref = jax.jit(ref.delta_scan_ref)
    err = _err(ops.delta_scan(fq, dvecs), dref(fq, dvecs))
    add("delta_scan_ref_xla", _time(dref, fq, dvecs, reps=reps), err)
    add("delta_scan_ops",
        _time(lambda: ops.delta_scan(fq, dvecs), reps=reps), err)

    # embedding bag
    table = r.normal(r.PRNGKey(9), (100_000, 16))
    ids = r.randint(r.PRNGKey(10), (1024, 26), 0, 100_000)
    jbag = jax.jit(ref.embedding_bag_ref)
    err = _err(ops.embedding_bag(table, ids), jbag(table, ids))
    add("embedding_bag_ref_xla", _time(jbag, table, ids, reps=reps), err)
    # embedding_bag's interpret-mode gather costs ~30s/call on CPU;
    # the single err check above already exercises the ops path

    for row in rows:
        us = "pending" if row["us"] is None else f"{row['us']:.1f}"
        err = "" if row["err"] is None else f"{row['err']:.2e}"
        print(f"{row['name']},{us},{err}")
    return {
        "rows": rows,
        "fused_sweep": fused_sweep,
        "sort": _sort_section(reps, smoke),
        "backend": jax.default_backend(),
        "pipelined_available": on_tpu,
        "tpu_speedup": "pending TPU run" if not on_tpu else None,
    }


if __name__ == "__main__":
    main()
