"""Benchmark entry point: one section per paper table/figure + the
beyond-paper serving table and kernel CSV.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke]

--full: 3x timing reps + bigger forests in Table 2 (slower). The
roofline table is produced separately from the dry-run artifacts via
``python -m benchmarks.roofline`` (it needs launch/dryrun.py output).
--smoke: minutes-scale CI mode — tiny substrate, one encoder, skips
the distribution/figure sections, but still writes (and therefore
validates) every JSON artifact: BENCH_kernels.json, BENCH_table2.json,
BENCH_serving.json.
"""
from __future__ import annotations

import json
import os
import sys
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _write(name: str, payload) -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.relpath(path)}")


def main() -> None:
    full = "--full" in sys.argv
    smoke = "--smoke" in sys.argv
    t0 = time.time()
    print("=" * 72)
    print("## Kernel micro-benchmarks (name,us_per_call,max_err)")
    from benchmarks import kernel_bench
    _write("BENCH_kernels.json", kernel_bench.main(smoke=smoke))

    if not smoke:
        print("=" * 72)
        print("## Paper §Classification: C(q) power law")
        from benchmarks import clabel_dist
        clabel_dist.main("star-like")

        print("=" * 72)
        print("## Paper Figure 1: phi_h saturation + Exit/Continue split")
        from benchmarks import figure1
        figure1.main("star-like")

    print("=" * 72)
    print("## Paper Table 2: early-exit strategies x 3 encoders")
    from benchmarks import table2
    _write("BENCH_table2.json", table2.main(quick=not full, smoke=smoke))

    print("=" * 72)
    print("## Beyond-paper: wave scheduler + live-mutation serving")
    from benchmarks import serving_bench
    _write("BENCH_serving.json", serving_bench.main("star-like",
                                                    smoke=smoke))

    if not smoke:
        print("=" * 72)
        try:
            from benchmarks import roofline
            rows = roofline.load_records("single")
            if rows:
                print("## Roofline (single-pod dry-run artifacts)")
                roofline.main("single")
            else:
                print("## Roofline: no dry-run artifacts yet "
                      "(run python -m repro.launch.dryrun --all)")
        except Exception as e:  # noqa: BLE001
            print(f"## Roofline skipped: {e}")
    print(f"\ntotal bench time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
