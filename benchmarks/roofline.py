"""§Roofline: three-term analysis per (arch × shape × mesh) from the
dry-run artifacts (launch/dryrun.py must have produced
artifacts/dryrun/*.json).

    compute_s    = HLO_FLOPs_per_device / 197e12      (bf16 peak, v5e)
    memory_s     = HLO_bytes_per_device / 819e9       (HBM)
    collective_s = collective_bytes_per_device / 50e9 (ICI per link)

cost_analysis is per-device (post-SPMD program). MODEL_FLOPS/HLO ratio
uses global MODEL_FLOPS / (per-device HLO_FLOPs * n_devices).
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

from repro.launch.hlo_analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                       roofline_terms)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                   "dryrun")


def load_records(mesh: str = "single") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyse(rec: Dict) -> Dict:
    if rec.get("status") != "ok" or "cost" not in rec:
        return {**rec, "ok": False}
    flops = rec["cost"]["flops"]
    bts = rec["cost"]["bytes"]
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    terms = roofline_terms(flops, bts, coll)
    n_dev = rec["n_devices"]
    mf = rec.get("model_flops_global", 0.0)
    useful = mf / (flops * n_dev) if flops else 0.0
    dom = terms["bottleneck"].replace("_s", "")
    t_dom = terms[terms["bottleneck"]]
    frac = {"compute": terms["compute_s"] / t_dom if t_dom else 0}
    return {
        "cell": f"{rec['arch']}:{rec['shape']}",
        "mesh": rec["mesh"],
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "bottleneck": dom,
        "roofline_frac": terms["compute_s"] / t_dom if t_dom > 0 else 0.0,
        "useful_flops_ratio": useful,
        "peak_gb": rec.get("memory", {}).get("peak_gb", float("nan")),
        "note": rec.get("note", ""),
        "ok": True,
    }


def main(mesh: str = "single") -> List[Dict]:
    rows = [analyse(r) for r in load_records(mesh)]
    ok = [r for r in rows if r.get("ok")]
    bad = [r for r in rows if not r.get("ok")]
    print(f"{'cell':42s} {'comp(ms)':>9s} {'mem(ms)':>9s} {'coll(ms)':>9s} "
          f"{'bound':>10s} {'frac':>6s} {'MF/HLO':>7s} {'peakGB':>7s}")
    for r in sorted(ok, key=lambda r: r["cell"]):
        print(f"{r['cell']:42s} {r['compute_s']*1e3:9.2f} "
              f"{r['memory_s']*1e3:9.2f} {r['collective_s']*1e3:9.2f} "
              f"{r['bottleneck']:>10s} {r['roofline_frac']:6.2f} "
              f"{r['useful_flops_ratio']:7.2f} {r['peak_gb']:7.1f}")
    if bad:
        print(f"\nFAILED cells: {[b.get('arch', '?') + ':' + b.get('shape', '?') for b in bad]}")
    out_path = os.path.join(ART, f"roofline_{mesh}.json")
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out_path}")
    return rows


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "single")
