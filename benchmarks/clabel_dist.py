"""Paper §Classification: C(q) follows a power law — ~half the queries
find their 1-NN in the first probed cluster; ~80% within ~tau probes."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import K, load_bench
from repro.core import min_probes_labels, probe_trace


def main(encoder: str = "star-like") -> dict:
    b = load_bench(encoder)
    q = jnp.asarray(b.corpus.queries[:2048])
    traj, _ = probe_trace(b.index, q, b.n_probe, K)
    labels = min_probes_labels(traj, b.exact_ids[:2048, 0], b.n_probe)
    out = {}
    print(f"C(q) distribution ({encoder}, N={b.n_probe})")
    for c in (1, 2, 5, 10, 20, b.n_probe):
        frac = float(np.mean(labels <= c))
        out[c] = frac
        print(f"  C(q) <= {c:3d}: {frac:6.1%}")
    # log-log slope as a power-law proxy
    cs = np.arange(1, 21)
    counts = np.array([(labels == c).sum() for c in cs]) + 1e-9
    slope = np.polyfit(np.log(cs), np.log(counts), 1)[0]
    print(f"  log-log slope over C in [1,20]: {slope:.2f} "
          f"(power law <=> strongly negative)")
    out["slope"] = slope
    return out


if __name__ == "__main__":
    main()
