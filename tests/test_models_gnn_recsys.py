"""GNN + RecSys smoke/learning tests (deliverable f)."""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.data.graph_sampler import CSRGraph, sample_blocks, pad_block
from repro.data.synthetic import click_log, random_graph
from repro.models import gnn, recsys
from repro.optim.optimizers import sgdm

RECSYS = ["deepfm", "dcn-v2", "xdeepfm", "two-tower-retrieval"]


@pytest.fixture(scope="module")
def small_graph():
    g = random_graph(128, 512, 16, 4, seed=0)
    return gnn.Graph(jnp.asarray(g["feat"]), jnp.asarray(g["edge_src"]),
                     jnp.asarray(g["edge_dst"]), jnp.asarray(g["label"]))


def test_gat_learns(small_graph):
    import dataclasses
    from repro.optim.optimizers import adamw
    cfg = dataclasses.replace(reduced(get_arch("gat-cora")).model,
                              d_in=16, n_classes=4)
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(0.02, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        (loss, _), grads = jax.value_and_grad(
            functools.partial(gnn.loss_fn, cfg), has_aux=True
        )(p, small_graph)
        p, s = opt.update(grads, s, p, i)
        return p, s, loss

    losses = []
    for i in range(120):
        params, state, loss = step(params, state, jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.3
    _, m = gnn.loss_fn(cfg, params, small_graph)
    assert float(m["acc"]) > 0.8        # community features separable


@pytest.mark.parametrize("agg", ["mean", "sum", "max"])
def test_aggregators_run(small_graph, agg):
    import dataclasses
    cfg = dataclasses.replace(reduced(get_arch("gat-cora")).model,
                              d_in=16, n_classes=4, aggregator=agg)
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    loss, _ = gnn.loss_fn(cfg, params, small_graph)
    assert np.isfinite(float(loss))


def test_sampler_block_invariants():
    g = random_graph(500, 4000, 8, 3, seed=1)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 500)
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 32, replace=False)
    blocks = sample_blocks(csr, seeds, (5, 3), rng)
    assert len(blocks) == 2
    for b, f, prev_n in zip(blocks, (5, 3), (32, None)):
        # dst nodes are a prefix
        assert b.n_out <= b.nodes.shape[0]
        e = b.edge_mask.sum()
        assert e <= b.n_out * f
        assert (b.edge_dst[b.edge_mask] < b.n_out).all()
        assert (b.edge_src[b.edge_mask] < b.nodes.shape[0]).all()
        # edges reference real graph edges
        src_g = b.nodes[b.edge_src[b.edge_mask]]
        dst_g = b.nodes[b.edge_dst[b.edge_mask]]
        for s_, d_ in list(zip(src_g, dst_g))[:20]:
            lo, hi = csr.indptr[d_], csr.indptr[d_ + 1]
            assert s_ in csr.indices[lo:hi]
    # chaining: outer block's nodes == inner block's dst prefix
    assert (blocks[1].nodes[: blocks[0].nodes.shape[0]]
            == blocks[0].nodes).all()


def test_minibatch_forward_matches_shapes():
    from repro.data.graph_sampler import block_shapes
    import dataclasses
    g = random_graph(500, 4000, 8, 3, seed=1)
    csr = CSRGraph.from_edges(g["edge_src"], g["edge_dst"], 500)
    rng = np.random.default_rng(0)
    seeds = rng.choice(500, 16, replace=False)
    blocks = sample_blocks(csr, seeds, (4, 3), rng)
    shapes = block_shapes(16, (4, 3))
    padded = [pad_block(b, e, n) for b, (e, n, _) in zip(blocks, shapes)]
    cfg = dataclasses.replace(reduced(get_arch("gat-cora")).model,
                              d_in=8, n_classes=3)
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    feats = jnp.asarray(g["feat"])[jnp.asarray(padded[-1].nodes)]
    bl = [{"edge_src": jnp.asarray(b.edge_src),
           "edge_dst": jnp.asarray(b.edge_dst),
           "edge_mask": jnp.asarray(b.edge_mask)} for b in padded]
    n_outs = tuple(o for (_, _, o) in shapes)
    out = gnn.forward_blocks(cfg, params, feats, bl, n_outs)
    assert out.shape == (16, 3)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_smoke(arch):
    cfg = reduced(get_arch(arch)).model
    data = click_log(32, cfg.n_dense, cfg.n_sparse, cfg.rows_per_field,
                     seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    loss, _ = recsys.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    logits = recsys.serve_logits(cfg, params, batch)
    assert logits.shape == (32,)
    grads = jax.grad(lambda p: recsys.loss_fn(cfg, p, batch)[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()


def test_deepfm_fm_term_matches_identity():
    """FM identity: sum_{i<j} <v_i, v_j> == 0.5*((sum v)^2 - sum v^2)."""
    cfg = reduced(get_arch("deepfm")).model
    rng = np.random.default_rng(0)
    emb = rng.normal(0, 1, (4, cfg.n_sparse, cfg.embed_dim)) \
        .astype(np.float32)
    sv = emb.sum(1)
    fast = 0.5 * (sv * sv - (emb * emb).sum(1)).sum(-1)
    slow = np.zeros(4, np.float32)
    for i in range(cfg.n_sparse):
        for j in range(i + 1, cfg.n_sparse):
            slow += (emb[:, i] * emb[:, j]).sum(-1)
    np.testing.assert_allclose(fast, slow, rtol=1e-4)


def test_two_tower_learns_and_retrieves():
    cfg = reduced(get_arch("two-tower-retrieval")).model
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    opt = sgdm(0.1, max_grad_norm=5.0)
    state = opt.init(params)
    data = click_log(64, 0, cfg.n_sparse, cfg.rows_per_field, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    first = None
    for i in range(30):
        (loss, m), grads = jax.value_and_grad(
            functools.partial(recsys.two_tower_loss, cfg),
            has_aux=True)(params, batch)
        params, state = opt.update(grads, state, params, jnp.asarray(i))
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.8
    u, v = recsys.tower_embeddings(cfg, params, batch)
    s, i = recsys.score_candidates(u[:2], v, k=8)
    assert s.shape == (2, 8) and (np.diff(np.asarray(s), 1) <= 1e-6).all()
