"""IVF index + search behaviour (the paper's data plane)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (brute_force, build_index, metrics, policies,
                        probe_trace, min_probes_labels, search)


def test_index_layout(tiny_index, tiny_corpus):
    offs = np.asarray(tiny_index.cluster_offsets)
    sizes = np.asarray(tiny_index.cluster_sizes)
    ids = np.asarray(tiny_index.doc_ids)
    assert (sizes <= tiny_index.list_pad).all()
    assert (offs % 64 == 0).all()                 # kernel alignment
    seen = []
    for c in range(len(offs)):
        sl = ids[offs[c]: offs[c] + sizes[c]]
        assert (sl >= 0).all()
        seen.append(sl)
    seen = np.concatenate(seen)
    assert len(np.unique(seen)) == tiny_corpus.docs.shape[0]


def test_docs_match_source(tiny_index, tiny_corpus):
    offs = np.asarray(tiny_index.cluster_offsets)
    sizes = np.asarray(tiny_index.cluster_sizes)
    ids = np.asarray(tiny_index.doc_ids)
    docs = np.asarray(tiny_index.docs)
    c = 3
    sl = slice(offs[c], offs[c] + sizes[c])
    np.testing.assert_allclose(docs[sl], tiny_corpus.docs[ids[sl]],
                               rtol=1e-6)


def test_fixed_recall_increases_with_n(tiny_index, tiny_corpus,
                                       tiny_exact):
    q = jnp.asarray(tiny_corpus.queries)
    recalls = []
    for n in (2, 8, 32):
        res = search(tiny_index, q, policies.fixed(n, k=10, tau=3))
        recalls.append(metrics.r_star_at_1(np.asarray(res.topk_ids),
                                           tiny_exact[1][:, 0]))
        assert (np.asarray(res.probes) == n).all()
    assert recalls[0] <= recalls[1] <= recalls[2]
    assert recalls[2] > 0.85


def test_full_probe_equals_brute_force(tiny_index, tiny_corpus,
                                       tiny_exact):
    q = jnp.asarray(tiny_corpus.queries)
    n = tiny_index.n_clusters
    res = search(tiny_index, q, policies.fixed(n, k=10, tau=3))
    assert metrics.r_star_at_1(np.asarray(res.topk_ids),
                               tiny_exact[1][:, 0]) == 1.0


def test_scores_sorted_and_ids_unique(tiny_index, tiny_corpus):
    q = jnp.asarray(tiny_corpus.queries)
    res = search(tiny_index, q, policies.fixed(16, k=10, tau=3))
    s = np.asarray(res.topk_scores)
    ids = np.asarray(res.topk_ids)
    assert (np.diff(s, axis=1) <= 1e-6).all()
    for row in ids:
        valid = row[row >= 0]
        assert len(np.unique(valid)) == len(valid)


def test_kernel_paths_match(tiny_index, tiny_corpus):
    q = jnp.asarray(tiny_corpus.queries[:64])
    pol = policies.patience(24, delta=3, phi=90.0, k=10, tau=3)
    a = search(tiny_index, q, pol)
    b = search(tiny_index, q, pol, use_scan_kernel=True,
               use_topk_kernel=True)
    assert (np.asarray(a.topk_ids) == np.asarray(b.topk_ids)).all()
    assert (np.asarray(a.probes) == np.asarray(b.probes)).all()


def test_labels_power_law(tiny_index, tiny_corpus, tiny_exact):
    """Paper §Classification: ~50% of queries need 1 probe; the
    distribution is heavy-tailed."""
    q = jnp.asarray(tiny_corpus.queries)
    traj, _ = probe_trace(tiny_index, q, 32, 10)
    lab = min_probes_labels(traj, tiny_exact[1][:, 0], 32)
    frac1 = float(np.mean(lab == 1))
    assert frac1 > 0.25                     # mass at C(q)=1
    assert float(np.mean(lab <= 10)) > frac1 + 0.1


def test_phi_saturates(tiny_index, tiny_corpus):
    """Paper Figure 1: mean intersection climbs toward 100%."""
    q = jnp.asarray(tiny_corpus.queries[:128])
    _, phi = probe_trace(tiny_index, q, 32, 10)
    mean = phi.mean(axis=1)
    assert mean[-1] > 85.0
    assert mean[-1] > mean[0]
