"""Crash safety: mutation WAL + snapshot recovery.

The core contract: a LiveIndex recovered from (latest snapshot + WAL
replay) after a crash injected at ANY mutation boundary serves
bit-identical results — top-k ids, probe counts, φ history — to the
instance that never crashed, on both the per-probe and fused kernel
paths.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointError, CheckpointManager
from repro.core import policies
from repro.index import (IndexRegistry, LiveIndex, MutationWAL,
                         WALCorruptError, version_of)
from repro.index.wal import OP_ADD, OP_DELETE
from repro.runtime.fault import SimulatedFailure


def _script(corpus, n_adds=5):
    """Deterministic mutation script: (op, payload) tuples."""
    rng = np.random.default_rng(42)
    ops = []
    for j in range(n_adds):
        vecs = (corpus.docs[rng.integers(0, 2000, 6)]
                + rng.normal(scale=0.03, size=(6, corpus.docs.shape[1]))
                ).astype(np.float32)
        ops.append(("add", vecs))
        if j == 1:
            ops.append(("delete_main", rng.integers(0, 2000, 4)))
        if j == 2:
            ops.append(("merge", None))
        if j == 3:
            ops.append(("delete_added", 2))   # delete 2 recent adds
    return ops


def _apply(live, op, payload, added):
    if op == "add":
        added.extend(int(i) for i in live.add(payload))
    elif op == "delete_main":
        live.delete(payload)
    elif op == "delete_added":
        doomed = added[-payload:]
        live.delete(doomed)
        del added[-payload:]
    else:
        live.merge_delta()


def _results(live, queries, **kw):
    pol = policies.patience(16, delta=2, phi=90.0, k=10, tau=3)
    r = live.search(jnp.asarray(queries), pol, **kw)
    return (np.asarray(r.topk_ids), np.asarray(r.probes),
            np.asarray(r.phi_hist))


@pytest.fixture(scope="module")
def small(tiny_corpus):
    from repro.core import build_index

    class C:
        docs = tiny_corpus.docs[:2000]
        queries = tiny_corpus.queries[:32]
    C.index = build_index(C.docs, 16, list_pad=256, n_iters=3, seed=0)
    return C


def test_kill_and_replay_every_boundary(small, tmp_path):
    """Inject a SimulatedFailure at every mutation boundary; recovery
    must be bit-identical to the uncrashed run on both kernel paths."""
    ops = _script(small)
    # uncrashed oracle
    oracle = LiveIndex(small.index, delta_cap=256)
    added_o = []
    for op, payload in ops:
        _apply(oracle, op, payload, added_o)
    want_pp = _results(oracle, small.queries)
    want_f = _results(oracle, small.queries, use_fused_kernel=True,
                      chunk=4)

    for crash_at in range(len(ops) + 1):
        workdir = tmp_path / f"boundary_{crash_at}"
        workdir.mkdir()
        wal = MutationWAL(str(workdir / "wal.log"))
        live = LiveIndex(small.index, delta_cap=256, wal=wal)
        mgr = CheckpointManager(str(workdir / "snaps"), async_save=False)
        IndexRegistry(version_of(live)).save(mgr)     # base snapshot
        added = []
        for op, payload in ops[:crash_at]:
            _apply(live, op, payload, added)
        with pytest.raises(SimulatedFailure):
            raise SimulatedFailure(f"kill @ boundary {crash_at}")
        del live                                      # process died
        _, recovered, rep = IndexRegistry.recover(mgr, wal)
        assert rep.applied == crash_at                # full replay
        for op, payload in ops[crash_at:]:
            _apply(recovered, op, payload, added)
        assert recovered.seq == oracle.seq
        assert recovered.next_id == oracle.next_id
        got_pp = _results(recovered, small.queries)
        got_f = _results(recovered, small.queries,
                         use_fused_kernel=True, chunk=4)
        for got, want in ((got_pp, want_pp), (got_f, want_f)):
            np.testing.assert_array_equal(got[0], want[0])
            np.testing.assert_array_equal(got[1], want[1])
            np.testing.assert_allclose(got[2], want[2], atol=1e-4)
        wal.close()


def test_recovery_from_mid_stream_snapshot(small, tmp_path):
    """Snapshot part-way + WAL truncation: replay resumes past it."""
    ops = _script(small)
    wal = MutationWAL(str(tmp_path / "wal.log"))
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    mgr = CheckpointManager(str(tmp_path / "snaps"), async_save=False)
    IndexRegistry(version_of(live)).save(mgr)
    added = []
    for op, payload in ops[:4]:
        _apply(live, op, payload, added)
    IndexRegistry(version_of(live)).save(mgr)
    kept = wal.truncate_upto(live.seq)
    assert kept == 0                          # snapshot covers the log
    for op, payload in ops[4:]:
        _apply(live, op, payload, added)
    _, recovered, rep = IndexRegistry.recover(mgr, wal)
    assert rep.applied == len(ops) - 4
    assert rep.skipped == 0
    np.testing.assert_array_equal(_results(recovered, small.queries)[0],
                                  _results(live, small.queries)[0])
    wal.close()


def test_torn_tail_is_tolerated(small, tmp_path):
    """A crash mid-append truncates the final record; replay drops it
    and reports torn_tail instead of dying."""
    path = str(tmp_path / "wal.log")
    wal = MutationWAL(path)
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    mgr = CheckpointManager(str(tmp_path / "snaps"), async_save=False)
    IndexRegistry(version_of(live)).save(mgr)
    live.add(small.docs[:4])
    live.add(small.docs[4:8])
    wal.close()
    with open(path, "rb") as f:
        full = f.read()
    with open(path, "wb") as f:               # tear the last record
        f.write(full[:-7])
    wal2 = MutationWAL(path)
    _, recovered, rep = IndexRegistry.recover(mgr, wal2)
    assert rep.torn_tail
    assert rep.applied == 1                   # only the intact record
    assert recovered.seq == 1
    wal2.close()


def test_mid_file_corruption_raises(small, tmp_path):
    path = str(tmp_path / "wal.log")
    wal = MutationWAL(path)
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    live.add(small.docs[:4])
    live.add(small.docs[4:8])
    wal.close()
    with open(path, "r+b") as f:              # flip payload bytes of
        f.seek(30)                            # the FIRST record
        f.write(b"\xff\xff\xff")
    wal2 = MutationWAL(path)
    with pytest.raises(WALCorruptError, match="(CRC|corrupt)"):
        wal2.scan()
    wal2.close()


def test_sequence_gap_raises(small, tmp_path):
    path = str(tmp_path / "wal.log")
    wal = MutationWAL(path)
    wal.append(OP_ADD, 1, small.docs[:2])
    wal.append(OP_DELETE, 3, np.asarray([0]))     # gap: seq 2 missing
    live = LiveIndex(small.index, delta_cap=256)
    with pytest.raises(WALCorruptError, match="sequence gap"):
        wal.replay_into(live)
    wal.close()


def test_wal_rejects_foreign_file(tmp_path):
    path = str(tmp_path / "notawal.bin")
    with open(path, "wb") as f:
        f.write(b"definitely not a WAL file")
    with pytest.raises(WALCorruptError, match="magic"):
        MutationWAL(path)


# -- group commit: batched fsync, unchanged recovery semantics --------------

def test_group_commit_batches_fsyncs(small, tmp_path):
    """group_commit_n batches appends into one fsync; merge boundaries
    and close() force the batch; recovery is still bit-identical."""
    path = str(tmp_path / "wal.log")
    wal = MutationWAL(path, group_commit_n=4)
    base = wal.fsyncs                         # header sync
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    mgr = CheckpointManager(str(tmp_path / "snaps"), async_save=False)
    IndexRegistry(version_of(live)).save(mgr)
    for j in range(3):                        # 3 pending, below n=4
        live.add(small.docs[4 * j: 4 * j + 4])
    assert wal.fsyncs == base                 # nothing durable yet
    assert wal._pending == 3
    live.add(small.docs[12:16])               # 4th append: batch lands
    assert wal.fsyncs == base + 1
    assert wal._pending == 0
    live.add(small.docs[16:20])               # 1 pending again
    live.merge_delta()                        # boundary: forced fsync
    assert wal.fsyncs == base + 2
    assert wal._pending == 0
    live.delete([0, 1])                       # pending at close
    wal.close()                               # close flushes the batch
    wal2 = MutationWAL(path)
    _, recovered, rep = IndexRegistry.recover(mgr, wal2)
    assert rep.applied == 7 and not rep.torn_tail
    assert recovered.seq == live.seq
    np.testing.assert_array_equal(
        _results(recovered, small.queries)[0],
        _results(live, small.queries)[0])
    for got, want in zip(
            _results(recovered, small.queries, use_fused_kernel=True,
                     chunk=4),
            _results(live, small.queries, use_fused_kernel=True,
                     chunk=4)):
        np.testing.assert_allclose(got, want, atol=1e-4)
    wal2.close()


def test_group_commit_ms_window_expires(small, tmp_path):
    """The time trigger fires on the next append once group_commit_ms
    has elapsed since the first pending record."""
    t = [0.0]
    wal = MutationWAL(str(tmp_path / "wal.log"), group_commit_n=100,
                      group_commit_ms=50.0, clock=lambda: t[0])
    base = wal.fsyncs
    wal.append(OP_ADD, 1, small.docs[:2])
    assert wal.fsyncs == base and wal._pending == 1
    t[0] = 0.010                              # 10ms: still inside window
    wal.append(OP_ADD, 2, small.docs[2:4])
    assert wal.fsyncs == base and wal._pending == 2
    t[0] = 0.060                              # 60ms > 50ms window
    wal.append(OP_ADD, 3, small.docs[4:6])
    assert wal.fsyncs == base + 1 and wal._pending == 0
    wal.close()


def test_group_commit_torn_tail_semantics_unchanged(small, tmp_path):
    """Tearing the final record of a group-committed log behaves
    exactly like the fsync-per-append WAL: the tail is dropped and
    reported, every earlier record replays."""
    path = str(tmp_path / "wal.log")
    wal = MutationWAL(path, group_commit_n=8)
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    mgr = CheckpointManager(str(tmp_path / "snaps"), async_save=False)
    IndexRegistry(version_of(live)).save(mgr)
    live.add(small.docs[:4])
    live.add(small.docs[4:8])
    live.add(small.docs[8:12])
    wal.close()                               # batch of 3 hits the disk
    with open(path, "rb") as f:
        full = f.read()
    with open(path, "wb") as f:               # tear the last record
        f.write(full[:-9])
    wal2 = MutationWAL(path)
    _, recovered, rep = IndexRegistry.recover(mgr, wal2)
    assert rep.torn_tail
    assert rep.applied == 2
    assert recovered.seq == 2
    wal2.close()


def test_group_commit_scan_sees_pending_records(small, tmp_path):
    """Pending (written-but-not-fsynced) records are OS-visible: scan
    returns them, so same-process recovery never loses a batch."""
    wal = MutationWAL(str(tmp_path / "wal.log"), group_commit_n=16)
    wal.append(OP_ADD, 1, small.docs[:2])
    wal.append(OP_DELETE, 2, np.asarray([0]))
    assert wal._pending == 2
    recs = wal.scan()
    assert [r.seq for r in recs] == [1, 2]
    wal.flush()
    assert wal._pending == 0
    wal.close()


# -- truncate guard: compaction can never outrun durability -----------------

def test_truncate_clamped_to_durable_snapshot(small, tmp_path):
    """truncate_upto is clamped to the last seq covered by a durable
    snapshot (note_durable): an over-eager compactor asking to cut the
    whole log keeps every record the snapshot does not cover."""
    ops = _script(small)
    wal = MutationWAL(str(tmp_path / "wal.log"))
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    mgr = CheckpointManager(str(tmp_path / "snaps"), async_save=False)
    added = []
    for op, payload in ops[:4]:
        _apply(live, op, payload, added)
    IndexRegistry(version_of(live)).save(mgr)
    wal.note_durable(live.seq)                # snapshot covers seq<=4
    durable_seq = live.seq
    for op, payload in ops[4:]:
        _apply(live, op, payload, added)
    # BUG SCENARIO: compactor asks to drop everything up to the tip
    kept = wal.truncate_upto(live.seq)
    assert kept == live.seq - durable_seq     # tail survived the cut
    assert [r.seq for r in wal.scan()] \
        == list(range(durable_seq + 1, live.seq + 1))
    # recovery is whole: snapshot + surviving tail == live state
    _, recovered, rep = IndexRegistry.recover(mgr, wal)
    assert rep.applied == live.seq - durable_seq
    np.testing.assert_array_equal(_results(recovered, small.queries)[0],
                                  _results(live, small.queries)[0])
    wal.close()


def test_truncate_respects_open_epoch_fence(small, tmp_path):
    """An interleaved compact-during-recovery sequence: compaction
    runs while a rebuild epoch is still open.  The cut is clamped to
    the fence seq and the fence records themselves survive, so a
    crash right after the compaction still aborts the epoch and
    replays every mutation; once the epoch closes, its fences (and
    the covered records) compact away."""
    from repro.index import Rebuilder
    from repro.index.wal import EPOCH_OPS
    ops = _script(small)
    wal = MutationWAL(str(tmp_path / "wal.log"))
    live = LiveIndex(small.index, delta_cap=256, wal=wal)
    mgr = CheckpointManager(str(tmp_path / "snaps"), async_save=False)
    IndexRegistry(version_of(live)).save(mgr)
    wal.note_durable(live.seq)
    added = []
    for op, payload in ops[:4]:
        _apply(live, op, payload, added)
    rb = Rebuilder(live, n_iters=2)           # no manager: stays open
    rb.request("compact-race")
    rb.tick()                                 # begin: fence at seq=4
    fence_seq = live.seq
    for op, payload in ops[4:]:
        _apply(live, op, payload, added)
    # snapshot up to the tip, then compact — mid-rebuild
    IndexRegistry(version_of(live)).save(mgr)
    wal.note_durable(live.seq)
    wal.truncate_upto(live.seq)
    recs = wal.scan()
    # everything after the fence survives, plus the fence itself
    assert [r.seq for r in recs if r.op not in EPOCH_OPS] \
        == list(range(fence_seq + 1, live.seq + 1))
    assert wal.open_epoch_fences(recs) == [fence_seq]
    # crash now: recovery aborts the open epoch and loses nothing
    _, recovered, rep = IndexRegistry.recover(mgr, wal)
    assert rep.rebuild_aborted
    np.testing.assert_array_equal(_results(recovered, small.queries)[0],
                                  _results(live, small.queries)[0])
    # the abort closed the epoch: compaction may now drop the fences
    wal.note_durable(live.seq)
    assert wal.truncate_upto(live.seq) == 0
    assert wal.scan() == []
    wal.close()


# -- satellite: actionable checkpoint errors --------------------------------

def test_missing_index_json_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    step_dir = tmp_path / "step_00000007"
    step_dir.mkdir()
    with pytest.raises(CheckpointError, match="index.json"):
        mgr.load_arrays(7)


def test_truncated_index_json_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    step_dir = tmp_path / "step_00000003"
    step_dir.mkdir()
    (step_dir / "index.json").write_text('{"step": 3, "keys": [')
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        mgr.load_arrays(3)


def test_truncated_array_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, {"a": np.arange(1000, dtype=np.float32)})
    arr = tmp_path / "step_00000005" / "arr_00000.npy"
    arr.write_bytes(arr.read_bytes()[:40])        # truncate the file
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        mgr.load_arrays(5)
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        mgr.restore({"a": np.zeros(1000, np.float32)}, step=5)


def test_missing_array_file_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(2, {"a": np.arange(8)})
    os.remove(tmp_path / "step_00000002" / "arr_00000.npy")
    with pytest.raises(CheckpointError, match="missing array file"):
        mgr.load_arrays(2)


def test_registry_restore_wrong_schema_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"weights": np.zeros(4)})         # not an index snapshot
    with pytest.raises(CheckpointError, match="IndexRegistry.save"):
        IndexRegistry.restore(mgr)
