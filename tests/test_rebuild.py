"""Background re-clustering: two-phase rebuild publish, epoch-fenced
swaps, WAL catch-up, drift trigger, and crash recovery at every
protocol boundary.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import policies, search
from repro.core.policies import DegradationLadder
from repro.core.serving import WaveScheduler
from repro.index import (DriftTracker, IndexRegistry, LiveIndex,
                         MutationWAL, RebuildCrash, Rebuilder,
                         StaleEpochError, version_of)
from repro.index.rebuild import FAILPOINTS, STAGES


@pytest.fixture(scope="module")
def small(tiny_corpus):
    from repro.core import build_index

    class C:
        docs = tiny_corpus.docs[:2000]
        queries = tiny_corpus.queries[:32]
        queries_long = tiny_corpus.queries[:96]
    C.index = build_index(C.docs, 16, list_pad=256, n_iters=3, seed=0)
    return C


def _results(live, queries, **kw):
    pol = policies.patience(16, delta=2, phi=90.0, k=10, tau=3)
    r = live.search(jnp.asarray(queries), pol, **kw)
    return (np.asarray(r.topk_ids), np.asarray(r.probes),
            np.asarray(r.phi_hist))


def _assert_same(a_live, b_live, queries):
    for kw in ({}, {"use_fused_kernel": True, "chunk": 4}):
        got = _results(a_live, queries, **kw)
        want = _results(b_live, queries, **kw)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])
        np.testing.assert_allclose(got[2], want[2], atol=1e-4)


def _mutate(live, rng, docs, added, n=6):
    vecs = (docs[rng.integers(0, len(docs), n)]
            + rng.normal(scale=0.05, size=(n, docs.shape[1]))
            ).astype(np.float32)
    added.extend(int(i) for i in live.add(vecs))
    live.delete([added.pop()])


def _wal_setup(small, tmp_path, tag):
    wdir = tmp_path / tag
    wdir.mkdir()
    wal = MutationWAL(str(wdir / "wal.log"))
    live = LiveIndex(small.index, delta_cap=512, wal=wal)
    mgr = CheckpointManager(str(wdir / "snaps"), async_save=False)
    reg = IndexRegistry(version_of(live))
    reg.save(mgr)
    wal.note_durable(live.seq)
    return wal, live, mgr, reg


# -- pipeline ---------------------------------------------------------------

def test_in_memory_rebuild_bumps_epoch_and_stays_equivalent(small):
    """A synchronous in-memory rebuild re-clusters the net corpus,
    bumps the epoch, loses no documents, and the published overlay
    stays bit-identical to a from-scratch layout of its own corpus."""
    rng = np.random.default_rng(0)
    live = LiveIndex(small.index, delta_cap=512)
    added = []
    for _ in range(3):
        _mutate(live, rng, small.docs, added)
    live.merge_delta()
    _mutate(live, rng, small.docs, added)
    before_ids = set(int(i) for i in live.net_corpus()[1])

    rb = Rebuilder(live, n_iters=2)
    rep = rb.run_once("test")
    new = rb.live
    assert rep is not None and rep.epoch == 1 and new.epoch == 1
    assert rep.corpus == len(before_ids)
    assert rep.reason == "test"
    assert rb.epochs_published == 1 and not rb.active
    assert set(int(i) for i in new.net_corpus()[1]) == before_ids
    _assert_same(new, _Static(new), small.queries)


class _Static:
    """Adapter: search a LiveIndex's from-scratch re-layout."""

    def __init__(self, live):
        self._idx = live.rebuild_equivalent()

    def search(self, q, pol, **kw):
        return search(self._idx, q, pol, **kw)


def test_rebuild_catches_up_mutations_between_stages(small, tmp_path):
    """Mutations that land between pipeline stages are WAL-replayed
    onto the candidate; the publish compacts the log and the promoted
    snapshot restores to the exact serving state."""
    wal, live, mgr, reg = _wal_setup(small, tmp_path, "catchup")
    rng = np.random.default_rng(1)
    added = []
    _mutate(live, rng, small.docs, added)
    reg.publish(version_of(live))

    rb = Rebuilder(live, reg, mgr, n_iters=2)
    assert rb.request("drill") and not rb.request("dup")
    stages = []
    while rb.active:
        stage = rb.tick()
        stages.append(stage)
        if stage in ("begin", "catchup"):
            _mutate(live, rng, small.docs, added)
            reg.publish(version_of(live))
    assert stages == list(STAGES)
    rep = rb.last_report
    assert rep.caught_up >= 4                  # two add+delete pairs
    assert reg.current().epoch == 1
    assert rep.step in mgr.all_steps()
    assert wal.scan() == []                    # compacted past cand.seq
    # no lost mutations: the candidate serves exactly the ids the
    # (fully caught-up) old handle knows about
    assert set(int(i) for i in rb.live.net_corpus()[1]) \
        == set(int(i) for i in live.net_corpus()[1])
    # durable roundtrip: recover == the published candidate
    _, recovered, _ = IndexRegistry.recover(mgr, wal)
    assert recovered.epoch == 1
    _assert_same(recovered, rb.live, small.queries)
    wal.close()


# -- crash boundaries -------------------------------------------------------

@pytest.mark.parametrize("fp", FAILPOINTS)
def test_crash_at_every_rebuild_boundary_recovers(small, tmp_path, fp):
    """Recovery after a crash at any two-phase-publish boundary is
    bit-identical: pre-COMMIT crashes land on the no-rebuild state,
    post-COMMIT crashes land on the rebuilt state, and a second
    recovery agrees with the first (idempotence)."""

    def drive(tag, failpoint):
        wal, live, mgr, reg = _wal_setup(small, tmp_path, tag)
        rng = np.random.default_rng(2)
        added = []
        _mutate(live, rng, small.docs, added)
        rb = Rebuilder(live, reg, mgr, n_iters=2, failpoint=failpoint)
        rb.request("crash-test")
        try:
            while rb.active:
                if rb.tick() == "begin":
                    _mutate(live, rng, small.docs, added)
        except RebuildCrash:
            pass
        return wal, live, mgr, rb

    wal, live, mgr, rb = drive(f"crash_{fp}", fp)
    _, recovered, rep = IndexRegistry.recover(mgr, wal)
    committed = recovered.epoch > 0
    assert committed == (fp in ("commit", "promote"))
    if committed:
        _, _, _, orb = drive(f"oracle_{fp}", None)
        oracle = orb.live
    else:
        assert rep.rebuild_aborted
        oracle = live            # only the Rebuilder crashed
    _assert_same(recovered, oracle, small.queries)
    # idempotence: recovering again lands on the same state
    _, again, _ = IndexRegistry.recover(mgr, wal)
    assert again.epoch == recovered.epoch
    _assert_same(again, recovered, small.queries)
    wal.close()


# -- epoch fencing ----------------------------------------------------------

def test_stale_epoch_publish_is_fenced(small):
    live = LiveIndex(small.index, delta_cap=512)
    reg = IndexRegistry(version_of(live))
    rb = Rebuilder(live, reg, n_iters=2)
    rb.run_once("fence-test")
    assert reg.current().epoch == 1
    with pytest.raises(StaleEpochError):
        reg.publish(version_of(live))          # stale epoch-0 handle
    assert reg.current().epoch == 1            # no clobber
    # same-epoch publishes (incl. the version-bump path) keep working
    # and carry the epoch through
    new = rb.live
    new.add(small.docs[:4])
    v1 = reg.publish(version_of(new))
    v2 = reg.publish(version_of(new))          # same version: bumped
    assert v2.epoch == v1.epoch == 1
    assert v2.version > v1.version


def test_rebuild_without_manager_closes_epoch_on_log(small, tmp_path):
    """With a WAL but no snapshot manager the rebuild cannot be made
    durable: the epoch is closed with an ABORT record so recovery
    lands on pre-rebuild centroids + full replay — consistent, no
    lost mutations, just not re-clustered."""
    wal = MutationWAL(str(tmp_path / "nomgr.log"))
    live = LiveIndex(small.index, delta_cap=512, wal=wal)
    rng = np.random.default_rng(3)
    added = []
    _mutate(live, rng, small.docs, added)
    rb = Rebuilder(live, n_iters=2)
    rb.run_once("no-mgr")
    assert rb.live.epoch == 1                  # in-memory swap happened
    assert wal.open_epoch_fences() == []       # ...but the log is closed
    mgr = CheckpointManager(str(tmp_path / "nomgr_snaps"),
                            async_save=False)
    IndexRegistry(version_of(LiveIndex(small.index, delta_cap=512))
                  ).save(mgr)
    _, recovered, rep = IndexRegistry.recover(mgr, wal)
    assert recovered.epoch == 0
    assert rep.applied >= 2                    # every mutation replayed
    assert set(int(i) for i in recovered.net_corpus()[1]) \
        == set(int(i) for i in rb.live.net_corpus()[1])
    wal.close()


# -- drift trigger ----------------------------------------------------------

def test_drift_tracker_triggers_and_rebases():
    rng = np.random.default_rng(4)
    cents = rng.normal(size=(8, 16)).astype(np.float32)
    near = (cents[rng.integers(0, 8, 256)]
            + rng.normal(scale=0.05, size=(256, 16))).astype(np.float32)
    tr = DriftTracker(cents, near, ema=0.5, threshold=1.5)
    assert tr.observe(near[:64]) == pytest.approx(1.0, rel=0.5)
    assert not tr.triggered
    far = (near[:64] + 10.0).astype(np.float32)
    for _ in range(4):
        tr.observe(far)
    assert tr.ratio > 1.5 and tr.triggered
    tr.rebase(cents + 10.0)                    # rebuilt onto the drift
    assert tr.ratio == 0.0 and not tr.triggered
    with pytest.raises(ValueError):
        DriftTracker(cents, ema=1.0)


def test_empty_corpus_rebuild_is_safe():
    """Re-clustering an index whose docs were all deleted must not
    divide by zero; centroids are kept as-is."""
    rng = np.random.default_rng(5)
    docs = rng.normal(size=(256, 8)).astype(np.float32)
    from repro.core import build_index
    idx = build_index(docs, 4, list_pad=128, n_iters=2, seed=0)
    live = LiveIndex(idx, delta_cap=128)
    live.delete(np.arange(256))
    rb = Rebuilder(live, n_iters=2)
    rep = rb.run_once("empty")
    assert rep.corpus == 0 and rb.live.epoch == 1
    np.testing.assert_allclose(np.asarray(rb.live._centroids),
                               np.asarray(live._centroids))


# -- serving-loop integration -----------------------------------------------

def test_scheduler_drains_lanes_before_epoch_swap(small, tmp_path):
    """A rebuild published mid-stream is adopted only after in-flight
    lanes drain (their probe order is invalid under new centroids);
    every query is still answered and the swap is counted."""
    wal, live, mgr, reg = _wal_setup(small, tmp_path, "sched")
    rng = np.random.default_rng(6)
    added = []
    handle = {"live": live}                    # on_publish rebinds it:
    # publishing from the pre-rebuild handle would be epoch-fenced

    def on_publish(new_live, report):
        handle["live"] = new_live

    rb = Rebuilder(live, reg, mgr, n_iters=2, on_publish=on_publish)
    ws = WaveScheduler(small.index, wave_size=8, chunk=4, k=10,
                       n_probe=16, delta=2, phi=90.0, registry=reg,
                       rebuilder=rb)

    def on_wave(wave):
        _mutate(handle["live"], rng, small.docs, added)
        reg.publish(version_of(handle["live"]))
        if wave == 1:
            rb.request("mid-stream")

    rep = ws.serve(small.queries_long, compact=True, on_wave=on_wave)
    assert len(rep.results) == len(small.queries_long)
    assert rb.epochs_published == 1
    assert rep.epoch_swaps == 1
    assert rep.rebuild_ticks >= len(STAGES)
    assert reg.current().epoch == 1
    wal.close()


def test_ladder_throttles_rebuild_under_deadline_pressure():
    lad = DegradationLadder(rebuild_pause_at=4.0)
    # no active lanes -> never throttle
    assert not lad.throttle_rebuild(np.array([]), 1.0)
    # comfortable budgets -> rebuild proceeds
    assert not lad.throttle_rebuild(np.array([10.0, 8.0]), 1.0)
    # ANY lane close to its deadline pauses background work
    assert lad.throttle_rebuild(np.array([10.0, 3.0]), 1.0)
    # thresholds scale with the wave cost estimate
    assert lad.throttle_rebuild(np.array([10.0, 8.0]), 4.0)
