"""Per-arch LM smoke tests: reduced config, one train + serve step on
CPU, shapes + no NaNs + prefill/decode consistency (deliverable f)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.models import transformer as tf

LM_ARCHS = ["minicpm3-4b", "qwen1.5-32b", "starcoder2-3b",
            "deepseek-moe-16b", "dbrx-132b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced(get_arch(arch)).model
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    loss, m = tf.loss_fn(cfg, params, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: tf.loss_fn(
        cfg, p, {"tokens": toks, "labels": toks})[0])(params)
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_consistency(arch):
    cfg = reduced(get_arch(arch)).model
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0,
                              cfg.vocab_size)
    lg_pref, cache = tf.prefill(cfg, params, toks[:, :16], max_seq=33)
    full, _ = tf.forward(cfg, params, toks)
    lp = jax.nn.log_softmax(lg_pref)
    lf = jax.nn.log_softmax(full[:, 15])
    assert float(jnp.max(jnp.abs(lp - lf))) < 0.15
    pos = 16
    for step in range(2):         # two decode steps
        lg, cache = tf.decode_step(cfg, params, cache,
                                   toks[:, pos: pos + 1],
                                   jnp.asarray(pos))
        err = float(jnp.max(jnp.abs(
            jax.nn.log_softmax(lg) - jax.nn.log_softmax(full[:, pos]))))
        assert err < 0.25, f"step {step}: {err}"
        pos += 1


def test_logits_shape_and_vocab():
    cfg = reduced(get_arch("qwen1.5-32b")).model
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 8), jnp.int32)
    logits, _ = tf.forward(cfg, params, toks)
    assert logits.shape == (2, 8, cfg.vocab_size)


def test_chunked_ce_matches_plain():
    from repro.models.layers import cross_entropy_loss
    cfg = reduced(get_arch("starcoder2-3b")).model
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                              cfg.vocab_size)
    loss, _ = tf.loss_fn(cfg, params, {"tokens": toks, "labels": toks},
                         ce_chunk=8)
    logits, aux = tf.forward(cfg, params, toks)
    ref = cross_entropy_loss(logits, toks) + aux
    assert abs(float(loss) - float(ref)) < 1e-4


def test_int8_cache_roundtrip():
    from repro.models.attention import quantize_kv, dequantize_kv
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1, 2, 32),
                          jnp.float32)
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02


def test_mla_cache_is_small():
    spec = get_arch("minicpm3-4b")
    cfg = spec.model
    cache = tf.abstract_cache(cfg, 1, 1024)
    mla_bytes = sum(np.prod(a.shape) * a.dtype.itemsize
                    for a in cache.data)
    # equivalent GQA cache for comparison
    full = cfg.n_layers * 2 * 1024 * cfg.n_heads * cfg.head_dim() * 2
    assert mla_bytes < full / 10     # >10x cache compression from MLA
