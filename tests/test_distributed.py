"""Multi-device semantics via subprocess (XLA_FLAGS must be set before
jax import, so these run in worker processes with 8 fake devices)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow   # subprocess-per-test: ~1 min total

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        "--xla_disable_hlo_passes=all-reduce-promotion")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PRELUDE = textwrap.dedent("""
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
""")


def test_distributed_ivf_matches_local():
    out = _run(PRELUDE + textwrap.dedent("""
        from repro.data.synthetic import clustered_corpus
        from repro.core import build_index, brute_force, metrics
        from repro.core.distributed_ivf import (shard_index,
                                                make_distributed_search)
        c = clustered_corpus(n_docs=8000, dim=24, n_components=64,
                             n_queries=64, seed=0)
        idx = build_index(c.docs, 64, list_pad=256, n_iters=4)
        sh = shard_index(idx, 4)
        fn = make_distributed_search(mesh, n_probe=64, k=10,
                                     patience_delta=None, list_pad=256)
        with mesh:
            res = fn(*map(jnp.asarray, (sh.centroids, sh.docs,
                                        sh.doc_ids, sh.offsets,
                                        sh.sizes)), jnp.asarray(c.queries))
        _, exact = brute_force(jnp.asarray(c.docs),
                               jnp.asarray(c.queries), 10)
        r = metrics.r_star_at_1(np.asarray(res.topk_ids),
                                np.asarray(exact)[:, 0])
        print(json.dumps({"recall": r}))
    """))
    # probing every cluster distributed == exhaustive
    assert out["recall"] == 1.0


def test_sharded_embedding_lookup_matches_dense():
    out = _run(PRELUDE + textwrap.dedent("""
        from repro.distributed.embedding import make_sharded_lookup
        rows, d = 64, 8
        table = jnp.asarray(
            np.random.default_rng(0).normal(0, 1, (rows, d))
            .astype(np.float32))
        ids = jnp.asarray(
            np.random.default_rng(1).integers(0, rows, (16, 5))
            .astype(np.int32))
        fn = make_sharded_lookup(mesh, rows)
        with mesh:
            out = fn(table, ids)
        exp = np.asarray(table)[np.asarray(ids)]
        err = float(np.max(np.abs(np.asarray(out) - exp)))
        print(json.dumps({"err": err}))
    """))
    assert out["err"] < 1e-5


def test_ring_all_gather_matches_xla():
    out = _run(PRELUDE + textwrap.dedent("""
        from repro.distributed.collectives import ring_all_gather
        x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)

        def local(xs):
            return ring_all_gather(xs, "model", 4)

        fn = jax.shard_map(local, mesh=mesh,
                           in_specs=P(None, "model"),
                           out_specs=P(None, None, "model"),
                           check_vma=False)
        with mesh:
            got = fn(x)                      # (4, 8, 1) chunks stacked
        chunks = [np.asarray(x)[:, i:i+1] for i in range(4)]
        exp = np.stack(chunks)
        err = float(np.max(np.abs(np.asarray(got) - exp)))
        print(json.dumps({"err": err}))
    """))
    assert out["err"] < 1e-6


def test_compressed_psum_approximates_mean():
    out = _run(PRELUDE + textwrap.dedent("""
        from repro.distributed.collectives import compressed_psum
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 1, (8, 64)).astype(np.float32))

        def local(gs):
            out, _ = compressed_psum(gs[0], jnp.zeros_like(gs[0]),
                                     "data")
            return out[None]

        fn = jax.shard_map(local, mesh=mesh,
                           in_specs=P("data", None),
                           out_specs=P("data", None), check_vma=False)
        with mesh:
            got = fn(g.reshape(2, 4, 64)[:, 0])   # 2 dp shards
        exp = np.asarray(g.reshape(2, 4, 64)[:, 0]).mean(0)
        err = float(np.max(np.abs(np.asarray(got)[0] - exp)))
        scale = float(np.abs(exp).max())
        print(json.dumps({"rel": err / (scale + 1e-9)}))
    """))
    assert out["rel"] < 0.02    # one int8 quantization step


def test_moe_sharded_matches_single_device():
    out = _run(PRELUDE + textwrap.dedent("""
        import dataclasses, functools
        from repro.configs import get_arch, reduced
        from repro.models import moe as moe_lib
        from repro.distributed.context import activation_mesh
        cfg = reduced(get_arch("dbrx-132b")).model
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32)
        ref_out, ref_aux = moe_lib.moe_forward(p, x, cfg)   # no mesh
        with mesh, activation_mesh(mesh):
            out, aux = jax.jit(
                lambda p_, x_: moe_lib.moe_forward(p_, x_, cfg))(p, x)
        err = float(jnp.max(jnp.abs(out - ref_out)))
        print(json.dumps({"err": err, "aux_err":
                          abs(float(aux) - float(ref_aux))}))
    """))
    assert out["err"] < 2e-2
    assert out["aux_err"] < 1e-3


def test_smoke_dryrun_cell_small_mesh():
    """dryrun machinery end-to-end on a small mesh (fast cell)."""
    out = _run(PRELUDE + textwrap.dedent("""
        from repro.launch import cells as cells_lib
        from repro.distributed.context import activation_mesh
        with mesh, activation_mesh(mesh):
            cell = cells_lib.build_cell("gat-cora", "molecule", mesh)
            compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                               out_shardings=cell.out_shardings,
                               donate_argnums=cell.donate_argnums
                               ).lower(*cell.args).compile()
            ca = compiled.cost_analysis()
        print(json.dumps({"flops": float(ca["flops"])}))
    """))
    assert out["flops"] > 0


def test_int8_doc_storage_matches_f32():
    out = _run(PRELUDE + textwrap.dedent("""
        from repro.data.synthetic import clustered_corpus
        from repro.core import build_index, brute_force, metrics
        from repro.core.distributed_ivf import (shard_index,
                                                quantize_sharded,
                                                make_distributed_search)
        c = clustered_corpus(n_docs=6000, dim=24, n_components=64,
                             n_queries=64, seed=3)
        idx = build_index(c.docs, 64, list_pad=256, n_iters=4)
        sh = quantize_sharded(shard_index(idx, 4))
        fn = make_distributed_search(mesh, n_probe=64, k=10,
                                     patience_delta=None, list_pad=256,
                                     int8_docs=True)
        with mesh:
            res = fn(*map(jnp.asarray, (sh.centroids, sh.docs,
                                        sh.doc_ids, sh.offsets,
                                        sh.sizes)),
                     jnp.asarray(c.queries),
                     jnp.asarray(sh.doc_scales))
        _, exact = brute_force(jnp.asarray(c.docs),
                               jnp.asarray(c.queries), 10)
        r = metrics.r_star_at_1(np.asarray(res.topk_ids),
                                np.asarray(exact)[:, 0])
        print(json.dumps({"recall": r}))
    """))
    assert out["recall"] >= 0.98    # int8 rounding can flip rare ties
