"""Packed (score, id) sort: the shared bitonic network and its
monotone f32 -> i32 key map (kernels/sort.py).

Edge cases the fused kernel and topk_merge lean on: exact-score ties,
NaN / -inf scores, tombstoned -1 ids, k larger than the candidate
count, and negative scores round-tripping the bit-pack exactly.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, sort


def _np_keys(vals):
    return np.asarray(sort.score_to_key(jnp.asarray(
        np.asarray(vals, np.float32))))


# -- key map ----------------------------------------------------------------

def test_key_map_roundtrips_bit_exactly():
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        (rng.normal(size=2000) * 10.0 ** rng.integers(-30, 30, 2000)
         ).astype(np.float32),
        np.float32([0.0, -0.0, 1e-44, -1e-44, np.inf, -np.inf,
                    -1e30, -1e29, 1e30, -3.5, 3.5]),
    ])
    keys = sort.score_to_key(jnp.asarray(vals))
    back = np.asarray(sort.key_to_score(keys))
    # bit-exact, including -0.0 vs 0.0 and denormals
    np.testing.assert_array_equal(back.view(np.int32),
                                  vals.view(np.int32))


def test_key_map_is_strictly_monotone_incl_negatives():
    rng = np.random.default_rng(1)
    vals = np.concatenate([
        rng.normal(scale=1e3, size=4000).astype(np.float32),
        np.float32([-np.inf, -1e30, -1e-40, -0.0, 0.0, 1e-40, np.inf]),
    ])
    keys = _np_keys(vals).astype(np.int64)
    i = rng.integers(0, vals.size, 8000)
    j = rng.integers(0, vals.size, 8000)
    np.testing.assert_array_equal(vals[i] < vals[j], keys[i] < keys[j])
    np.testing.assert_array_equal(vals[i] > vals[j], keys[i] > keys[j])


def test_host_key_of_matches_device_map():
    for x in (-1e30, -1e29, 0.25, -0.25, float("-inf"), 1e30):
        assert sort.key_of(x) == int(_np_keys([x])[0])


# -- packed network ---------------------------------------------------------

def _sorted_packed(scores, ids):
    out = sort.bitonic_desc_packed(sort.pack(
        sort.score_to_key(jnp.asarray(np.asarray(scores, np.float32))),
        jnp.asarray(np.asarray(ids, np.int32))))
    return (np.asarray(sort.key_to_score(out[:, 0])),
            np.asarray(out[:, 1]))


def test_matches_lexsort_on_random_rows():
    rng = np.random.default_rng(2)
    sc = rng.normal(size=(8, 64)).astype(np.float32)
    ids = rng.integers(0, 1 << 29, size=(8, 64)).astype(np.int32)
    out_s, out_i = _sorted_packed(sc, ids)
    keys = _np_keys(sc).astype(np.int64)
    for r in range(8):
        order = np.lexsort((-ids[r].astype(np.int64), -keys[r]))
        np.testing.assert_array_equal(out_s[r], sc[r][order])
        np.testing.assert_array_equal(out_i[r], ids[r][order])


def test_score_ties_break_by_id_descending():
    sc = np.full((1, 8), 2.5, np.float32)
    ids = np.asarray([[3, 7, 1, 5, 0, 6, 2, 4]], np.int32)
    _, out_i = _sorted_packed(sc, ids)
    np.testing.assert_array_equal(out_i[0], [7, 6, 5, 4, 3, 2, 1, 0])


def test_tombstone_ids_sink_below_real_candidates():
    # equal sentinel scores: -1 ids must lose ties against every real id
    sc = np.asarray([[1.0, -1e30, 2.0, -1e30]], np.float32)
    ids = np.asarray([[10, -1, 20, -1]], np.int32)
    out_s, out_i = _sorted_packed(sc, ids)
    np.testing.assert_array_equal(out_i[0], [20, 10, -1, -1])
    np.testing.assert_array_equal(out_s[0][:2], [2.0, 1.0])


def test_mark_helpers_preserve_minus_one():
    ids = jnp.asarray([[5, -1, 0, (1 << 29)]], jnp.int32)
    marked = sort.mark_new(ids)
    np.testing.assert_array_equal(
        np.asarray(marked),
        [[5 | sort.NEW_MARK, -1, sort.NEW_MARK,
          (1 << 29) | sort.NEW_MARK]])
    np.testing.assert_array_equal(np.asarray(sort.is_marked(marked)),
                                  [[True, False, True, True]])
    np.testing.assert_array_equal(np.asarray(sort.strip_marks(marked)),
                                  np.asarray(ids))


# -- through the topk_merge kernel wrapper ----------------------------------

def test_nan_and_neg_inf_scores_become_empty_slots():
    s = jnp.asarray([[np.nan, 1.0, -np.inf, np.nan]], jnp.float32)
    i = jnp.asarray([[7, 8, 9, 10]], jnp.int32)
    ns = jnp.asarray([[2.0, -np.inf]], jnp.float32)
    ni = jnp.asarray([[11, 12]], jnp.int32)
    out_s, out_i = ops.topk_merge(s, i, ns, ni, 4)
    np.testing.assert_array_equal(np.asarray(out_s[0])[:2], [2.0, 1.0])
    np.testing.assert_array_equal(np.asarray(out_i[0])[:2], [11, 8])
    # NaN / -inf candidates are demoted to empty (-inf) slots
    assert np.all(np.isneginf(np.asarray(out_s[0])[2:]))


def test_k_larger_than_candidate_count_pads_with_empty():
    s = jnp.full((2, 3), -jnp.inf, jnp.float32)
    i = jnp.full((2, 3), -1, jnp.int32)
    ns = jnp.asarray([[4.0, 3.0], [1.0, -jnp.inf]], jnp.float32)
    ni = jnp.asarray([[100, 200], [300, -1]], jnp.int32)
    out_s, out_i = ops.topk_merge(s, i, ns, ni, 5)
    np.testing.assert_array_equal(np.asarray(out_i),
                                  [[100, 200, -1, -1, -1],
                                   [300, -1, -1, -1, -1]])
    assert np.all(np.isneginf(np.asarray(out_s[0])[2:]))
    assert np.all(np.isneginf(np.asarray(out_s[1])[1:]))


def test_negative_scores_survive_merge_exactly():
    rng = np.random.default_rng(3)
    s = jnp.asarray(-np.abs(rng.normal(size=(4, 10))).astype(np.float32))
    i = jnp.asarray(rng.integers(0, 1000, (4, 10)).astype(np.int32))
    ns = jnp.asarray(
        -np.abs(rng.normal(size=(4, 30))).astype(np.float32) - 5.0)
    ni = jnp.asarray(rng.integers(1000, 2000, (4, 30)).astype(np.int32))
    out_s, out_i = ops.topk_merge(s, i, ns, ni, 10)
    cat_s = np.concatenate([np.asarray(s), np.asarray(ns)], axis=1)
    cat_i = np.concatenate([np.asarray(i), np.asarray(ni)], axis=1)
    for r in range(4):
        order = np.argsort(-cat_s[r], kind="stable")[:10]
        # all-negative inputs round-trip the bit-pack with zero error
        np.testing.assert_array_equal(np.sort(np.asarray(out_s[r])),
                                      np.sort(cat_s[r][order]))
        np.testing.assert_array_equal(np.sort(np.asarray(out_i[r])),
                                      np.sort(cat_i[r][order]))


@pytest.mark.parametrize("m", [2, 8, 128, 512])
def test_network_sizes_power_of_two(m):
    rng = np.random.default_rng(m)
    sc = rng.normal(size=(2, m)).astype(np.float32)
    ids = rng.integers(0, 1 << 20, size=(2, m)).astype(np.int32)
    out_s, _ = _sorted_packed(sc, ids)
    np.testing.assert_array_equal(out_s, -np.sort(-sc, axis=1))
