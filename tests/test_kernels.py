"""Pallas kernels vs jnp oracles: shape/dtype sweeps (deliverable c)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("s,hd,blk", [(128, 64, 64), (256, 64, 128),
                                      (256, 128, 64), (512, 32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, hd, blk, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, s, hd), dtype)
    k = jax.random.normal(k2, (2, s, hd), dtype)
    v = jax.random.normal(k3, (2, s, hd), dtype)
    out = ops.flash_attention(q, k, v, blk_q=blk, blk_k=blk)
    exp = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_non_causal():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (2, 128, 32))
    k = jax.random.normal(k2, (2, 128, 32))
    v = jax.random.normal(k3, (2, 128, 32))
    out = ops.flash_attention(q, k, v, causal=False, blk_q=64, blk_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("d,lp,blk", [(32, 128, 64), (64, 256, 64),
                                      (16, 64, 64)])
def test_ivf_scan_sweep(d, lp, blk):
    rng = np.random.default_rng(0)
    docs = jnp.asarray(rng.normal(0, 1, (1024, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(0, 1, (6, d)).astype(np.float32))
    offsets = jnp.asarray(
        (rng.integers(0, (1024 - lp) // blk, 6) * blk).astype(np.int32))
    sizes = jnp.asarray(rng.integers(0, lp + 1, 6).astype(np.int32))
    out = ops.ivf_scan(q, docs, offsets, sizes, list_pad=lp, blk_l=blk)
    exp = ref.ivf_scan_ref(q, docs, offsets, sizes, lp)
    finite = np.isfinite(np.asarray(exp))
    assert (np.isfinite(np.asarray(out)) == finite).all()
    np.testing.assert_allclose(np.asarray(out)[finite],
                               np.asarray(exp)[finite], rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("k,l,b", [(8, 24, 4), (16, 48, 8), (10, 100, 3),
                                   (100, 256, 2)])
def test_topk_merge_sweep(k, l, b):
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.normal(0, 1, (b, k)).astype(np.float32))
    i = jnp.asarray(rng.integers(0, 10_000, (b, k)).astype(np.int32))
    ns = jnp.asarray(rng.normal(0, 1, (b, l)).astype(np.float32))
    ni = jnp.asarray(rng.integers(10_000, 20_000, (b, l)).astype(np.int32))
    os_, oi_ = ops.topk_merge(s, i, ns, ni, k)
    es, ei = ref.topk_merge_ref(s, i, ns, ni, k)
    np.testing.assert_allclose(np.asarray(os_), np.asarray(es),
                               rtol=1e-6)
    # ids must agree except where scores tie (random floats: no ties)
    assert (np.asarray(oi_) == np.asarray(ei)).all()


def test_topk_merge_with_neg_inf():
    s = jnp.asarray([[-np.inf, -np.inf]], jnp.float32)
    i = jnp.asarray([[-1, -1]], jnp.int32)
    ns = jnp.asarray([[1.0, 2.0, 0.5]], jnp.float32)
    ni = jnp.asarray([[7, 8, 9]], jnp.int32)
    os_, oi_ = ops.topk_merge(s, i, ns, ni, 2)
    assert oi_.tolist() == [[8, 7]]


def test_topk_merge_empty_slots_stay_neg_inf():
    """The in-kernel -1e30 sentinel must not leak: when fewer than k
    candidates exist, empty output slots are exactly -inf, bit-matching
    the XLA merge path."""
    s = jnp.full((2, 4), -np.inf, jnp.float32)
    i = jnp.full((2, 4), -1, jnp.int32)
    ns = jnp.asarray([[3.0, -np.inf, -np.inf],
                      [-np.inf, -np.inf, -np.inf]], jnp.float32)
    ni = jnp.asarray([[5, -1, -1], [-1, -1, -1]], jnp.int32)
    os_, oi_ = ops.topk_merge(s, i, ns, ni, 4)
    es, ei = ref.topk_merge_ref(s, i, ns, ni, 4)
    assert np.array_equal(np.asarray(os_), np.asarray(es))
    assert np.isneginf(np.asarray(os_)[0, 1:]).all()
    assert np.isneginf(np.asarray(os_)[1]).all()


@pytest.mark.parametrize("r,d,b,f", [(50, 8, 4, 3), (200, 16, 8, 5),
                                     (1000, 32, 2, 10)])
def test_embedding_bag_sweep(r, d, b, f):
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(0, 1, (r, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, r, (b, f)).astype(np.int32))
    out = ops.embedding_bag(table, ids)
    exp = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_ragged_embedding_bag_oracle():
    """The take+segment_sum EmbeddingBag construction (taxonomy §RecSys)."""
    from repro.distributed.embedding import embedding_bag
    rng = np.random.default_rng(3)
    table = jnp.asarray(rng.normal(0, 1, (40, 6)).astype(np.float32))
    ids = jnp.asarray([0, 1, 2, 5, 5, 7, 39], jnp.int32)
    offsets = jnp.asarray([0, 3, 3, 6, 7], jnp.int32)   # bag1 empty
    out = embedding_bag(table, ids, offsets)
    t = np.asarray(table)
    exp = np.stack([t[[0, 1, 2]].sum(0), np.zeros(6),
                    t[[5, 5, 7]].sum(0), t[39]])
    np.testing.assert_allclose(np.asarray(out), exp, rtol=1e-6)
