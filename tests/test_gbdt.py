"""Histogram GBDT (LightGBM stand-in) + JAX inference parity."""
import numpy as np
import jax.numpy as jnp

from repro.trees.gbdt import GBDT
from repro.trees.jax_infer import from_numpy_forest, predict_margin, \
    predict_proba
from repro.trees.smote import smote


def _make_reg_data(n=2000, f=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, f)).astype(np.float32)
    y = 3.0 * x[:, 0] - 2.0 * x[:, 1] ** 2 + 0.5 * x[:, 2] * x[:, 3] \
        + rng.normal(0, 0.1, n)
    return x, y


def test_regression_fits():
    x, y = _make_reg_data()
    m = GBDT("l2", n_trees=40, max_depth=4, learning_rate=0.2)
    f = m.fit(x[:1500], y[:1500], eval_set=(x[1500:], y[1500:]))
    pred = m.predict(f, x[1500:])
    base = np.mean((y[1500:] - y[:1500].mean()) ** 2)
    mse = np.mean((pred - y[1500:]) ** 2)
    assert mse < 0.35 * base


def test_classification_fits():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (2000, 6)).astype(np.float32)
    y = (x[:, 0] + x[:, 1] * x[:, 2] > 0).astype(np.float64)
    m = GBDT("logistic", n_trees=40, max_depth=4, learning_rate=0.3)
    f = m.fit(x[:1500], y[:1500], eval_set=(x[1500:], y[1500:]))
    p = m.predict(f, x[1500:])
    acc = np.mean((p > 0.5) == y[1500:])
    assert acc > 0.85


def test_jax_inference_matches_numpy():
    x, y = _make_reg_data(800, 5, seed=2)
    m = GBDT("l2", n_trees=15, max_depth=4)
    f = m.fit(x, y)
    ens = from_numpy_forest(f, m.max_depth)
    np_pred = m.predict_margin(f, x[:100])
    jx_pred = np.asarray(predict_margin(ens, jnp.asarray(x[:100])))
    np.testing.assert_allclose(jx_pred, np_pred, rtol=1e-5, atol=1e-5)


def test_instance_weights_shift_decision():
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1, (1500, 4)).astype(np.float32)
    y = (x[:, 0] > 0.5).astype(np.float64)       # imbalanced (~30% pos)
    m = GBDT("logistic", n_trees=20, max_depth=3)
    f_plain = m.fit(x, y)
    w = np.where(y == 1, 8.0, 1.0)
    f_w = m.fit(x, y, sample_weight=w)
    p_plain = m.predict(f_plain, x)
    p_w = m.predict(f_w, x)
    # upweighting positives must raise predicted positive rate
    assert (p_w > 0.5).mean() > (p_plain > 0.5).mean()


def test_early_stopping_truncates():
    x, y = _make_reg_data(1200, 5, seed=4)
    m = GBDT("l2", n_trees=200, max_depth=3, early_stopping=5)
    f = m.fit(x[:800], y[:800], eval_set=(x[800:], y[800:]))
    assert len(f.trees) < 200


def test_smote_balances():
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (500, 4)).astype(np.float32)
    y = np.zeros(500)
    y[:50] = 1.0
    xa, ya = smote(x, y, k=3, seed=0)
    assert (ya == 1).sum() == (ya == 0).sum()
    assert xa.shape[0] == ya.shape[0] > 500
    # synthetic points lie within the minority bounding box-ish region
    mino = x[:50]
    synth = xa[500:]
    assert synth.min() >= mino.min() - 1e-5
    assert synth.max() <= mino.max() + 1e-5
