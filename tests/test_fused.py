"""Fused scan->merge kernel + chunked search parity (DESIGN §2).

The chunked while-loop and the fused Pallas path must be *bit-identical*
to the per-probe baseline: same top-k ids, same per-query probe counts,
same phi history — for heuristic and learned policies alike.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import policies, search
from repro.core.training import train_policy_models
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def cascade_policy(tiny_index, tiny_corpus):
    qs = tiny_corpus.queries
    models = train_policy_models(
        tiny_index, tiny_corpus.docs, qs[:128], qs[128:192],
        n_probe=24, k=10, tau=3, n_trees=10, max_depth=3)
    return policies.cascade_patience(
        24, models.clf_weighted, delta=3, phi=90.0, k=10, tau=3)


def _policy(name, cascade):
    if name == "patience":
        return policies.patience(24, delta=2, phi=90.0, k=10, tau=3)
    if name == "fixed":
        return policies.fixed(12, k=10, tau=3)
    return cascade


@pytest.mark.parametrize("chunk", [2, 4, 5])
@pytest.mark.parametrize("policy_name", ["patience", "fixed", "cascade"])
def test_chunked_search_matches_per_probe(tiny_index, tiny_corpus,
                                          cascade_policy, chunk,
                                          policy_name):
    pol = _policy(policy_name, cascade_policy)
    q = jnp.asarray(tiny_corpus.queries[:64])
    base = search(tiny_index, q, pol)
    chunked = search(tiny_index, q, pol, chunk=chunk)
    assert np.array_equal(np.asarray(base.topk_ids),
                          np.asarray(chunked.topk_ids))
    assert np.array_equal(np.asarray(base.probes),
                          np.asarray(chunked.probes))


@pytest.mark.parametrize("policy_name", ["patience", "fixed", "cascade"])
def test_fused_search_matches_baseline(tiny_index, tiny_corpus,
                                       cascade_policy, policy_name):
    pol = _policy(policy_name, cascade_policy)
    q = jnp.asarray(tiny_corpus.queries[:64])
    base = search(tiny_index, q, pol)
    fused = search(tiny_index, q, pol, use_fused_kernel=True, chunk=4)
    assert np.array_equal(np.asarray(base.topk_ids),
                          np.asarray(fused.topk_ids))
    assert np.array_equal(np.asarray(base.probes),
                          np.asarray(fused.probes))
    assert np.allclose(np.asarray(base.phi_hist),
                       np.asarray(fused.phi_hist), atol=1e-4)


def test_fused_kernel_matches_ref():
    """Direct kernel-vs-oracle parity: scores, ids and the per-probe
    new-entry counts (the phi signal) on disjoint aligned clusters."""
    rng = np.random.default_rng(3)
    B, chunk, lp, k, d = 4, 3, 256, 10, 16
    n = 64 * lp
    docs = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int32)
    qs = jnp.asarray(rng.normal(size=(B, d)).astype(np.float32))
    # disjoint per-query lists so each doc id is scored at most once
    offs = np.stack([rng.choice(n // lp, chunk, replace=False) * lp
                     for _ in range(B)]).astype(np.int32)
    sizes = rng.integers(1, lp + 1, size=(B, chunk)).astype(np.int32)
    sizes[0, 1] = 0                        # empty probe slot
    rs = jnp.full((B, k), -jnp.inf, jnp.float32)
    ri = jnp.full((B, k), -1, jnp.int32)

    o_s, o_i, o_c = ops.ivf_scan_merge(
        qs, docs, ids, jnp.asarray(offs), jnp.asarray(sizes), rs, ri,
        k=k, list_pad=lp, chunk=chunk)
    r_s, r_i, r_c = ref.ivf_scan_merge_ref(
        qs, docs, ids, jnp.asarray(offs), jnp.asarray(sizes), rs, ri,
        k, lp)

    # -inf empty slots must match exactly (sentinel mapped back)
    np.testing.assert_array_equal(np.isneginf(np.asarray(o_s)),
                                  np.isneginf(np.asarray(r_s)))
    np.testing.assert_allclose(
        np.nan_to_num(np.asarray(o_s), neginf=0.0),
        np.nan_to_num(np.asarray(r_s), neginf=0.0), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(o_i), np.asarray(r_i))
    np.testing.assert_array_equal(np.asarray(o_c), np.asarray(r_c))
    # phi recovered from counts == intersection_pct of the snapshots
    from repro.core.ivf import intersection_pct
    prev = ri
    for t in range(chunk):
        phi_cnt = 100.0 * (k - np.asarray(o_c)[:, t]) / k
        phi_ref = np.asarray(intersection_pct(prev, o_i[:, t]))
        np.testing.assert_allclose(phi_cnt, phi_ref, atol=1e-4)
        prev = o_i[:, t]


# -- dispatch accounting ----------------------------------------------------

def _count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call eqns in a (closed) jaxpr."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            objs = v if isinstance(v, (list, tuple)) else [v]
            for o in objs:
                inner = getattr(o, "jaxpr", None)
                if inner is not None:
                    n += _count_pallas_calls(
                        getattr(inner, "jaxpr", inner))
    return n


def test_fused_delta_path_is_one_dispatch_per_chunk(tiny_index,
                                                    tiny_corpus):
    """With a live delta buffer, the fused path must issue exactly ONE
    Pallas dispatch per chunk — the delta scan and every per-slot merge
    happen inside the kernel, with no host-side XLA re-merge and no
    separate delta_scan launch."""
    import jax
    from repro.index import LiveIndex

    live = LiveIndex(tiny_index, delta_cap=128)
    live.add(tiny_corpus.docs[:32] + np.float32(0.01))
    live.delete([int(i) for i in np.asarray(tiny_index.doc_ids)[:2]])
    view = live.delta_view()
    pol = policies.patience(16, delta=2, phi=90.0, k=10, tau=3)
    q = jnp.asarray(tiny_corpus.queries[:8])

    from repro.core.ivf import _search
    jaxpr = jax.make_jaxpr(
        lambda qq: _search(tiny_index, qq, pol, view,
                           use_scan_kernel=False, use_topk_kernel=False,
                           use_fused_kernel=True, chunk=4, blk_l=64)
    )(q)
    # the while-loop body advances one chunk per iteration: exactly one
    # pallas_call anywhere in the whole search jaxpr
    assert _count_pallas_calls(jaxpr.jaxpr) == 1
    # and the result still matches the rebuilt-index oracle
    res = live.search(q, pol, use_fused_kernel=True, chunk=4)
    oracle = search(live.rebuild_equivalent(), q, pol,
                    use_fused_kernel=True, chunk=4)
    np.testing.assert_array_equal(np.asarray(res.topk_ids),
                                  np.asarray(oracle.topk_ids))


def test_fused_no_delta_is_one_dispatch_per_chunk(tiny_index,
                                                  tiny_corpus):
    import jax
    from repro.core.ivf import _search
    pol = policies.patience(16, delta=2, phi=90.0, k=10, tau=3)
    q = jnp.asarray(tiny_corpus.queries[:8])
    jaxpr = jax.make_jaxpr(
        lambda qq: _search(tiny_index, qq, pol, None,
                           use_scan_kernel=False, use_topk_kernel=False,
                           use_fused_kernel=True, chunk=4, blk_l=64)
    )(q)
    assert _count_pallas_calls(jaxpr.jaxpr) == 1
