"""Deadline-budgeted degradation ladder, shard retry/backoff, and the
seeded chaos harness."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import brute_force, metrics, policies
from repro.core.distributed_ivf import (ShardFault, search_with_retry,
                                        shard_index)
from repro.core.policies import (DEGRADE_REASONS, RUNG_CAP, RUNG_FORCE,
                                 RUNG_NONE, RUNG_TIGHTEN,
                                 DegradationLadder)
from repro.core.serving import WaveScheduler
from repro.runtime.chaos import ChaosConfig, ChaosMonkey, SimClock
from repro.runtime.straggler import RetryPolicy


# -- degradation ladder -----------------------------------------------------

def test_ladder_rungs_vectorized():
    lad = DegradationLadder(tighten_at=3.0, cap_at=1.5, force_at=0.0)
    remaining = np.array([10.0, 4.0, 2.9, 1.4, 0.0, -1.0])
    rungs = lad.rungs(remaining, wave_cost_ms=1.0)
    np.testing.assert_array_equal(
        rungs, [RUNG_NONE, RUNG_NONE, RUNG_TIGHTEN, RUNG_CAP,
                RUNG_FORCE, RUNG_FORCE])


def test_ladder_scales_with_wave_cost():
    lad = DegradationLadder()
    # 5 ms left is comfortable when waves cost 1 ms, dire at 4 ms
    assert lad.rungs(np.array([5.0]), 1.0)[0] == RUNG_NONE
    assert lad.rungs(np.array([5.0]), 4.0)[0] >= RUNG_TIGHTEN


def test_ladder_validates_ordering():
    with pytest.raises(ValueError):
        DegradationLadder(tighten_at=1.0, cap_at=2.0)


def test_degrade_reason_vocabulary():
    assert set(DEGRADE_REASONS) == {"tightened_patience", "capped_probes",
                                    "forced_exit", "shed"}


# -- deadline-budgeted serving ----------------------------------------------

@pytest.fixture(scope="module")
def served(tiny_index, tiny_corpus):
    """Serve the stream under a tight deadline with a deterministic
    simulated clock (2 ms per wave)."""
    clock = SimClock()
    ws = WaveScheduler(tiny_index, wave_size=16, chunk=1, k=10,
                       n_probe=16, delta=3, phi=90.0, deadline_ms=5.0,
                       clock=clock)
    queries = tiny_corpus.queries[:64]
    rep = ws.serve(queries, on_wave=lambda w: clock.advance(2.0))
    return rep, queries


def test_deadline_every_query_served(served):
    rep, queries = served
    assert set(rep.results) == set(range(queries.shape[0]))
    assert rep.deadline_ms == 5.0


def test_deadline_overshoot_bounded_by_one_wave(served):
    """No query may exceed its budget by more than one probe's worth of
    work (chunk=1 => one wave)."""
    rep, _ = served
    wave_ms = 2.0
    for qid, lat in rep.latency_ms.items():
        assert lat <= rep.deadline_ms + wave_ms + 1e-9, \
            f"query {qid} overshot: {lat:.2f}ms vs {rep.deadline_ms}ms"


def test_deadline_degraded_queries_have_reasons(served):
    rep, _ = served
    assert rep.degraded, "tight deadline must degrade some queries"
    for qid, reason in rep.degraded.items():
        assert reason in DEGRADE_REASONS
        assert qid in rep.results
    # anything that ran past the budget must carry a reason
    for qid, lat in rep.latency_ms.items():
        if lat > rep.deadline_ms:
            assert qid in rep.degraded
    assert 0.0 < rep.degraded_fraction <= 1.0


def test_no_deadline_no_degradation(tiny_index, tiny_corpus):
    ws = WaveScheduler(tiny_index, wave_size=16, chunk=4, k=10,
                       n_probe=16, delta=3, phi=90.0)
    rep = ws.serve(tiny_corpus.queries[:32])
    assert rep.degraded == {}
    assert rep.deadline_ms is None
    assert rep.degraded_fraction == 0.0


def test_deadline_sheds_admissions_when_hopeless(tiny_index, tiny_corpus):
    """Once a wave costs more than the whole budget, new admissions are
    shed with empty results rather than queued to certain failure."""
    clock = SimClock()
    ws = WaveScheduler(tiny_index, wave_size=4, chunk=1, k=10,
                       n_probe=16, delta=3, phi=90.0, deadline_ms=1.0,
                       clock=clock)
    rep = ws.serve(tiny_corpus.queries[:32],
                   on_wave=lambda w: clock.advance(4.0))
    shed = rep.shed_ids()
    assert shed, "4 ms waves under a 1 ms budget must shed"
    for qid in shed:
        assert rep.degraded[qid] == "shed"
        assert np.all(rep.results[qid] == -1)
        assert rep.probes[qid] == 0
    # shed queries still appear exactly once in the report
    assert set(rep.results) == set(range(32))


def test_deadline_recall_monotone(tiny_index, tiny_corpus):
    """Looser budgets must not hurt recall (chunk=1, fixed wave cost)."""
    queries = tiny_corpus.queries[:64]
    _, exact = brute_force(jnp.asarray(tiny_corpus.docs),
                           jnp.asarray(queries), 10)
    exact = np.asarray(exact)
    recalls = []
    for dl in (2.0, 8.0, None):
        clock = SimClock()
        ws = WaveScheduler(tiny_index, wave_size=16, chunk=1, k=10,
                           n_probe=16, delta=3, phi=90.0,
                           deadline_ms=dl, clock=clock)
        rep = ws.serve(queries, on_wave=lambda w: clock.advance(1.0))
        ids = np.stack([rep.results[i] for i in range(64)])
        recalls.append(metrics.r_star_at_k(ids, exact))
    assert recalls[0] <= recalls[1] + 1e-9 <= recalls[2] + 2e-9


# -- shard retry with backoff -----------------------------------------------

def test_retry_policy_backoff_schedule():
    rp = RetryPolicy(max_retries=5, base_ms=1.0, multiplier=2.0,
                     max_ms=6.0)
    assert [rp.backoff_ms(a) for a in range(4)] == [1.0, 2.0, 4.0, 6.0]
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(multiplier=0.5)


def test_shard_retry_flaky_recovers(tiny_index, tiny_corpus):
    """A shard that fails twice then succeeds must yield results
    identical to the clean run, with the retries accounted for."""
    queries = tiny_corpus.queries[:16]
    sh = shard_index(tiny_index, 4)
    _, ids_clean, rep_clean = search_with_retry(sh, queries, k=10,
                                                n_probe=16)
    assert rep_clean.retries == 0 and not rep_clean.skipped_shards

    fails = {"left": 2}

    def flaky(shard, attempt):
        if shard == 1 and fails["left"] > 0:
            fails["left"] -= 1
            raise ShardFault("flaky shard 1")

    slept = []
    _, ids, rep = search_with_retry(
        sh, queries, k=10, n_probe=16,
        retry=RetryPolicy(max_retries=3, base_ms=1.0, multiplier=2.0),
        fault=flaky, sleep=slept.append)
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(ids_clean))
    assert rep.retries == 2
    assert not rep.skipped_shards
    assert slept == [1.0, 2.0]          # exponential backoff observed


def test_shard_retry_dead_shard_skipped(tiny_index, tiny_corpus):
    """A shard that never recovers is skipped after max_retries and its
    clusters recorded as lost; the query still gets an answer."""
    queries = tiny_corpus.queries[:16]
    sh = shard_index(tiny_index, 4)

    def dead(shard, attempt):
        if shard == 0:
            raise ShardFault("shard 0 is gone")

    _, ids, rep = search_with_retry(
        sh, queries, k=10, n_probe=16,
        retry=RetryPolicy(max_retries=2, base_ms=0.5),
        fault=dead, sleep=lambda ms: None)
    assert rep.skipped_shards == [0]
    assert rep.lost_clusters > 0
    assert rep.retries == 2
    ids = np.asarray(ids)
    assert ids.shape == (16, 10)
    assert (ids >= 0).all(), "surviving shards must still fill top-k"


def test_retry_decorrelated_jitter_bounded_and_spread():
    """Decorrelated draws stay in [base, min(3*prev, max)] and two rng
    streams de-synchronise; jitter='none' is the classic schedule."""
    rp = RetryPolicy(max_retries=4, base_ms=1.0, max_ms=8.0,
                     jitter="decorrelated")
    rng = np.random.default_rng(7)
    prev = 0.0
    draws = []
    for attempt in range(5):
        ms = rp.next_backoff(attempt, prev, rng)
        lo, hi = 1.0, min(max(1.0, 3.0 * (prev or 1.0)), 8.0)
        assert lo <= ms <= hi
        draws.append(ms)
        prev = ms
    other = []
    rng2 = np.random.default_rng(8)
    prev = 0.0
    for attempt in range(5):
        ms = rp.next_backoff(attempt, prev, rng2)
        other.append(ms)
        prev = ms
    assert draws != other                 # herds spread apart
    none = RetryPolicy(max_retries=4, base_ms=1.0, multiplier=2.0)
    assert [none.next_backoff(a, 99.0) for a in range(3)] \
        == [none.backoff_ms(a) for a in range(3)]
    with pytest.raises(ValueError):
        RetryPolicy(jitter="gaussian")
    with pytest.raises(ValueError):
        RetryPolicy(budget_ms=0.0)


def test_retry_budget_exhaustion_degrades_to_skip(tiny_index,
                                                  tiny_corpus):
    """Once the per-query backoff budget is burned, faulting shards are
    skipped immediately (no further sleeps) and accounted — the query
    still gets an answer from the surviving shards."""
    queries = tiny_corpus.queries[:16]
    sh = shard_index(tiny_index, 4)

    def all_dead(shard, attempt):
        raise ShardFault(f"shard {shard} is gone")

    slept = []
    _, ids, rep = search_with_retry(
        sh, queries, k=10, n_probe=16,
        retry=RetryPolicy(max_retries=3, base_ms=4.0, multiplier=2.0,
                          budget_ms=10.0),
        fault=all_dead, sleep=slept.append)
    assert rep.budget_exhausted
    assert rep.budget_skips > 0
    # total sleep is clamped to exactly the budget, never beyond
    assert sum(slept) == pytest.approx(10.0)
    assert rep.backoff_ms == pytest.approx(10.0)
    # every shard was still attempted once (first try is free) and
    # ends up skipped with its clusters accounted
    assert rep.skipped_shards == [0, 1, 2, 3]
    assert np.asarray(ids).shape == (16, 10)


def test_retry_budget_not_hit_when_healthy(tiny_index, tiny_corpus):
    """A finite budget is inert when shards are healthy or recover
    within it: same results, no budget accounting."""
    queries = tiny_corpus.queries[:16]
    sh = shard_index(tiny_index, 4)
    _, ids_clean, _ = search_with_retry(sh, queries, k=10, n_probe=16)
    fails = {"left": 1}

    def flaky(shard, attempt):
        if shard == 2 and fails["left"] > 0:
            fails["left"] -= 1
            raise ShardFault("one blip")

    _, ids, rep = search_with_retry(
        sh, queries, k=10, n_probe=16,
        retry=RetryPolicy(max_retries=3, base_ms=1.0, budget_ms=50.0),
        fault=flaky, sleep=lambda ms: None)
    assert not rep.budget_exhausted and rep.budget_skips == 0
    assert rep.budget_ms == 50.0
    np.testing.assert_array_equal(np.asarray(ids),
                                  np.asarray(ids_clean))


# -- chaos harness ----------------------------------------------------------

def test_chaos_monkey_deterministic():
    a, b = ChaosMonkey(ChaosConfig(seed=3)), ChaosMonkey(ChaosConfig(seed=3))
    assert [a.wave_ms() for _ in range(20)] == \
           [b.wave_ms() for _ in range(20)]


def test_chaos_end_to_end(tiny_index, tiny_corpus, tmp_path):
    from repro.runtime.chaos import run_chaos

    queries = tiny_corpus.queries[:32]
    _, exact = brute_force(jnp.asarray(tiny_corpus.docs),
                           jnp.asarray(queries), 10)
    cfg = ChaosConfig(seed=1, mutation_steps=8, adds_per_step=6,
                      crash_every=3, snapshot_every=4,
                      shard_fault_rate=0.4)
    payload = run_chaos(tiny_index, tiny_corpus.docs, queries,
                        np.asarray(exact), cfg, str(tmp_path),
                        k=10, n_probe=16, deadlines_ms=[2.0, 10.0])
    rec = payload["recovery"]
    assert rec["crashes"] > 0
    assert rec["replayed_records"] > 0
    assert rec["bit_identical"] is True
    curve = payload["deadline_curve"]
    assert len(curve) == 3               # 2 deadlines + unconstrained row
    assert curve[-1]["deadline_ms"] is None
    assert curve[-1]["degraded_fraction"] == 0.0
    for row in curve:
        assert 0.0 <= row["recall"] <= 1.0
    assert payload["shard_faults"]["attempts"] >= cfg.n_shards
