"""Live-mutation subsystem: the rebuild-equivalence invariant.

A query against (main index + delta buffer + tombstones) must return
bit-identical top-k ids and probe counts to the same query against a
freshly rebuilt index containing the net corpus — for every exit
policy, on both the per-probe and fused kernel paths.  That is the
contract that makes `merge_delta` a pure background optimisation
instead of a semantic event.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import brute_force, build_index, policies, search
from repro.core.training import train_policy_models
from repro.index import (DeltaFull, IndexRegistry, LiveIndex, relayout,
                         version_of)


@pytest.fixture(scope="module")
def cascade_policy(tiny_index, tiny_corpus):
    qs = tiny_corpus.queries
    models = train_policy_models(
        tiny_index, tiny_corpus.docs, qs[:128], qs[128:192],
        n_probe=24, k=10, tau=3, n_trees=10, max_depth=3)
    return policies.cascade_patience(
        24, models.clf_weighted, delta=3, phi=90.0, k=10, tau=3)


@pytest.fixture()
def mutated(tiny_index, tiny_corpus):
    """LiveIndex after a burst of adds and deletes (main + buffered)."""
    live = LiveIndex(tiny_index, delta_cap=512)
    rng = np.random.default_rng(11)
    new = tiny_corpus.docs[rng.choice(len(tiny_corpus.docs), 160,
                                      replace=False)]
    new = new + rng.normal(scale=0.05, size=new.shape).astype(np.float32)
    added = live.add(new)
    live.delete(rng.choice(8000, 120, replace=False))     # main docs
    live.delete(added[::5])                               # buffered docs
    return live


def _policy(name, cascade):
    if name == "patience":
        return policies.patience(24, delta=2, phi=90.0, k=10, tau=3)
    if name == "fixed":
        return policies.fixed(12, k=10, tau=3)
    return cascade


@pytest.mark.parametrize("fused", [False, True], ids=["perprobe", "fused"])
@pytest.mark.parametrize("policy_name", ["fixed", "patience", "cascade"])
def test_rebuild_equivalence(mutated, tiny_corpus, cascade_policy,
                             policy_name, fused):
    pol = _policy(policy_name, cascade_policy)
    q = jnp.asarray(tiny_corpus.queries[:64])
    kw = dict(use_fused_kernel=True, chunk=4) if fused else {}
    live = mutated.search(q, pol, **kw)
    rebuilt = search(mutated.rebuild_equivalent(), q, pol, **kw)
    np.testing.assert_array_equal(np.asarray(live.topk_ids),
                                  np.asarray(rebuilt.topk_ids))
    np.testing.assert_array_equal(np.asarray(live.probes),
                                  np.asarray(rebuilt.probes))
    np.testing.assert_allclose(np.asarray(live.phi_hist),
                               np.asarray(rebuilt.phi_hist), atol=1e-4)


def test_full_probe_matches_brute_force(mutated, tiny_corpus):
    """Probing every cluster over the live view == exact kNN over the
    net corpus (external-id space)."""
    q = jnp.asarray(tiny_corpus.queries[:32])
    pol = policies.fixed(mutated.index.n_clusters, k=10, tau=3)
    res = mutated.search(q, pol)
    vecs, ids = mutated.net_corpus()
    _, rows = brute_force(jnp.asarray(vecs), q, 10)
    np.testing.assert_array_equal(np.asarray(res.topk_ids),
                                  ids[np.asarray(rows)])


def test_merge_delta_preserves_results(mutated, tiny_corpus):
    q = jnp.asarray(tiny_corpus.queries[:48])
    pol = policies.patience(24, delta=2, phi=90.0, k=10, tau=3)
    before = mutated.search(q, pol)
    n_live = mutated.n_live
    ver = mutated.merge_delta()
    assert ver == 1
    assert len(mutated.delta) == 0          # everything fit
    assert mutated.n_live == n_live
    after = mutated.search(q, pol)
    np.testing.assert_array_equal(np.asarray(before.topk_ids),
                                  np.asarray(after.topk_ids))
    np.testing.assert_array_equal(np.asarray(before.probes),
                                  np.asarray(after.probes))


def test_merge_delta_spills_overfull_cluster(tiny_index, tiny_corpus):
    """Adds targeting one nearly-full cluster spill back into the
    buffer instead of overflowing list_pad."""
    live = LiveIndex(tiny_index, delta_cap=512)
    c0 = np.asarray(tiny_index.centroids)[0]
    rng = np.random.default_rng(3)
    crowd = (c0[None, :]
             + rng.normal(scale=1e-3, size=(300, c0.size))).astype(np.float32)
    live.add(crowd)
    assert (live.delta.assign[:300] == 0).all()
    fill0 = int(np.asarray(tiny_index.cluster_sizes)[0])
    live.merge_delta()
    spilled = len(live.delta)
    assert spilled == max(0, fill0 + 300 - tiny_index.list_pad)
    assert spilled > 0
    # spilled docs stay searchable through the overlay
    q = jnp.asarray(tiny_corpus.queries[:16])
    pol = policies.fixed(12, k=10, tau=3)
    res = live.search(q, pol)
    oracle = search(live.rebuild_equivalent(), q, pol)
    np.testing.assert_array_equal(np.asarray(res.topk_ids),
                                  np.asarray(oracle.topk_ids))


def test_delete_semantics(tiny_index):
    live = LiveIndex(tiny_index)
    live.delete([5, 5, 17])                 # dup in one call
    live.delete(5)                          # double delete: no-op
    assert live.tombs.count == 2
    assert live.n_live == 8000 - 2
    with pytest.raises(ValueError, match="never allocated"):
        live.delete(999999)


def test_delta_full_raises(tiny_index, tiny_corpus):
    live = LiveIndex(tiny_index, delta_cap=128)
    with pytest.raises(DeltaFull, match="merge_delta"):
        live.add(tiny_corpus.docs[:200])


def test_alignment_validation(tiny_index, tiny_corpus):
    from repro.core import validate_alignment
    from repro.core.ivf import IVFIndex
    with pytest.raises(ValueError, match="align"):
        build_index(tiny_corpus.docs[:512], 4, list_pad=256, align=0)
    with pytest.raises(ValueError, match="multiple of align"):
        build_index(tiny_corpus.docs[:512], 4, list_pad=100, align=64)
    skewed = IVFIndex(tiny_index.centroids, tiny_index.docs,
                      tiny_index.doc_ids,
                      tiny_index.cluster_offsets + 1,
                      tiny_index.cluster_sizes, tiny_index.list_pad)
    with pytest.raises(ValueError, match="aligned"):
        validate_alignment(skewed)
    q = jnp.asarray(tiny_corpus.queries[:4])
    pol = policies.fixed(4, k=10, tau=3)
    with pytest.raises(ValueError, match="build_index"):
        search(skewed, q, pol, use_fused_kernel=True, chunk=2)


def test_relayout_rejects_overfull_cluster(tiny_corpus):
    vecs = tiny_corpus.docs[:300]
    ids = np.arange(300, dtype=np.int32)
    assign = np.zeros(300, np.int32)
    cents = np.zeros((4, vecs.shape[1]), np.float32)
    with pytest.raises(ValueError, match="list_pad"):
        relayout(vecs, ids, assign, cents, list_pad=256)


def test_registry_checkpoint_roundtrip(mutated, tiny_corpus, tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    reg = IndexRegistry(version_of(mutated))
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    reg.save(mgr)
    reg2, ver = IndexRegistry.restore(mgr)
    assert ver.next_id == mutated.next_id
    q = jnp.asarray(tiny_corpus.queries[:32])
    pol = policies.patience(24, delta=2, phi=90.0, k=10, tau=3)
    a = search(mutated.index, q, pol, delta=mutated.delta_view())
    b = search(ver.index, q, pol, delta=ver.delta)
    np.testing.assert_array_equal(np.asarray(a.topk_ids),
                                  np.asarray(b.topk_ids))
    np.testing.assert_array_equal(np.asarray(a.probes),
                                  np.asarray(b.probes))


def test_registry_publish_monotonic(mutated):
    reg = IndexRegistry(version_of(mutated, version=3))
    assert reg.current().version == 3
    reg.publish(version_of(mutated, version=1))     # stale: bumped
    assert reg.current().version == 4
    assert reg.swaps == 2


# -- tombstone edge cases ---------------------------------------------------

def test_delete_id_only_in_delta_buffer(tiny_index, tiny_corpus):
    """Deleting a doc that was never merged (lives only in the buffer)
    must drop it from search and from the net corpus."""
    live = LiveIndex(tiny_index, delta_cap=128)
    added = live.add(tiny_corpus.docs[:8]
                     + np.float32(0.01))            # near-duplicates
    target = int(added[3])
    live.delete(target)
    assert target in live.tombs
    vecs, ids = live.net_corpus()
    assert target not in ids
    q = jnp.asarray(tiny_corpus.queries[:32])
    pol = policies.fixed(tiny_index.n_clusters, k=10, tau=3)
    res = live.search(q, pol)
    assert not np.isin(np.asarray(res.topk_ids), target).any()
    oracle = search(live.rebuild_equivalent(), q, pol)
    np.testing.assert_array_equal(np.asarray(res.topk_ids),
                                  np.asarray(oracle.topk_ids))


def test_double_delete_is_idempotent(tiny_index, tiny_corpus):
    """Deleting the same id twice (buffered or main) is a no-op the
    second time — counts don't double, search is unchanged."""
    live = LiveIndex(tiny_index, delta_cap=128)
    added = live.add(tiny_corpus.docs[:4] + np.float32(0.01))
    main_id = int(np.asarray(tiny_index.doc_ids).max()) // 2
    for victim in (int(added[0]), main_id):
        live.delete(victim)
        n_live = live.n_live
        dead = live.tombs.count
        live.delete(victim)                         # again
        assert live.n_live == n_live
        assert live.tombs.count == dead
    live.delete([main_id, main_id])                 # dup within one call
    assert live.tombs.count == 2
    q = jnp.asarray(tiny_corpus.queries[:16])
    pol = policies.patience(16, delta=2, phi=90.0, k=10, tau=3)
    res = live.search(q, pol)
    oracle = search(live.rebuild_equivalent(), q, pol)
    np.testing.assert_array_equal(np.asarray(res.topk_ids),
                                  np.asarray(oracle.topk_ids))


def test_delete_then_readd_across_merge_boundary(tiny_index, tiny_corpus):
    """Delete a doc, merge, then add the same vector back: the old id
    stays dead, the re-add gets a fresh id, and the overlay still
    matches a rebuild."""
    live = LiveIndex(tiny_index, delta_cap=128)
    vec = tiny_corpus.docs[100:101] + np.float32(0.01)
    (old_id,) = (int(i) for i in live.add(vec))
    live.delete(old_id)
    live.merge_delta()                              # boundary
    assert old_id in live.tombs
    (new_id,) = (int(i) for i in live.add(vec))
    assert new_id > old_id                          # ids never recycled
    assert new_id not in live.tombs
    vecs, ids = live.net_corpus()
    assert old_id not in ids and new_id in ids
    q = jnp.asarray(tiny_corpus.queries[:32])
    for kw in ({}, {"use_fused_kernel": True, "chunk": 4}):
        pol = policies.patience(16, delta=2, phi=90.0, k=10, tau=3)
        res = live.search(q, pol, **kw)
        oracle = search(live.rebuild_equivalent(), q, pol, **kw)
        np.testing.assert_array_equal(np.asarray(res.topk_ids),
                                      np.asarray(oracle.topk_ids))
    assert not np.isin(np.asarray(res.topk_ids), old_id).any()
