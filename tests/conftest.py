"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests run on the
single real CPU device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build_index, brute_force
from repro.data.synthetic import clustered_corpus


@pytest.fixture(scope="session")
def tiny_corpus():
    return clustered_corpus(n_docs=8000, dim=24, n_components=64,
                            n_queries=256, seed=7)


@pytest.fixture(scope="session")
def tiny_index(tiny_corpus):
    return build_index(tiny_corpus.docs, 64, list_pad=256, n_iters=4,
                       seed=0)


@pytest.fixture(scope="session")
def tiny_exact(tiny_corpus):
    s, i = brute_force(jnp.asarray(tiny_corpus.docs),
                       jnp.asarray(tiny_corpus.queries), 10)
    return np.asarray(s), np.asarray(i)
