"""Property-based tests (hypothesis) over system invariants.

``hypothesis`` is an optional test dependency (see the ``test`` extra
in pyproject.toml); the module is skipped when it is absent so the
rest of the suite still collects.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core.ivf import intersection_pct
from repro.kernels import ops, ref
from repro.optim.optimizers import clip_by_global_norm, global_norm
from repro.distributed.collectives import compress_int8, decompress_int8


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 30), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_intersection_pct_invariants(k, b, seed):
    # ids in a result set are unique by construction (clusters are
    # disjoint); -1 marks empty slots
    rng = np.random.default_rng(seed)
    rows = []
    for _ in range(b):
        ids = rng.choice(60, size=k, replace=False).astype(np.int32)
        ids[rng.random(k) < 0.2] = -1
        rows.append(ids)
    a = jnp.asarray(np.stack(rows), jnp.int32)
    val = np.asarray(intersection_pct(a, a))
    # NOTE: duplicate -1 slots never count (masked), so val <= 100
    assert (val >= 0).all() and (val <= 100.0 + 1e-6).all()
    other = jnp.flip(a, axis=1)
    ab = np.asarray(intersection_pct(a, other))
    # permutation invariance of the second set
    np.testing.assert_allclose(ab, val, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 64), st.integers(1, 6),
       st.integers(0, 2 ** 31 - 1))
def test_topk_merge_matches_ref(k, l, b, seed):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.normal(0, 1, (b, k)).astype(np.float32))
    i = jnp.asarray(rng.integers(0, 1000, (b, k)).astype(np.int32))
    ns = jnp.asarray(rng.normal(0, 1, (b, l)).astype(np.float32))
    ni = jnp.asarray(rng.integers(1000, 2000, (b, l)).astype(np.int32))
    os_, oi_ = ops.topk_merge(s, i, ns, ni, k)
    es, ei = ref.topk_merge_ref(s, i, ns, ni, k)
    np.testing.assert_allclose(np.asarray(os_), np.asarray(es),
                               rtol=1e-6)
    assert (np.asarray(oi_) == np.asarray(ei)).all()
    # output sorted descending
    assert (np.diff(np.asarray(os_), axis=1) <= 1e-7).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.floats(0.1, 10.0),
       st.integers(0, 2 ** 31 - 1))
def test_clip_by_global_norm(n, max_norm, seed):
    rng = np.random.default_rng(seed)
    g = {"a": jnp.asarray(rng.normal(0, 3, (n,)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(0, 3, (3, 2)).astype(np.float32))}
    clipped = clip_by_global_norm(g, max_norm)
    assert float(global_norm(clipped)) <= max_norm * (1 + 1e-4)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 256), st.integers(0, 2 ** 31 - 1))
def test_int8_compression_error_feedback(n, seed):
    """Error feedback: sum of transmitted values converges to the sum of
    true values (residual stays bounded by one quantization step)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, (n,)).astype(np.float32))
    err = jnp.zeros_like(g)
    sent = jnp.zeros_like(g)
    for _ in range(5):
        q, scale, err = compress_int8(g, err)
        sent = sent + decompress_int8(q, scale)
    # after T rounds of the SAME gradient: sent ~= T*g with bounded err
    resid = np.asarray(sent - 5 * g)
    step = float(jnp.max(jnp.abs(g + err))) / 127.0 + 1e-6
    assert np.max(np.abs(resid)) <= 2 * step + 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 50), st.integers(2, 16),
       st.integers(0, 2 ** 31 - 1))
def test_embedding_bag_property(rows, f, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(0, 1, (rows, 4)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, rows, (3, f)).astype(np.int32))
    out = ops.embedding_bag(table, ids)
    exp = ref.embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-4, atol=1e-5)


def _tiny_live_substrate(seed):
    """Small corpus + index shared across hypothesis examples (module
    cache keyed on nothing: the corpus is fixed, mutations vary)."""
    global _LIVE_CACHE
    try:
        return _LIVE_CACHE
    except NameError:
        from repro.core import build_index
        rng = np.random.default_rng(99)
        docs = rng.normal(size=(600, 8)).astype(np.float32)
        index = build_index(docs, 8, list_pad=128, n_iters=3, seed=0)
        _LIVE_CACHE = (docs, index)
        return _LIVE_CACHE


@settings(max_examples=10, deadline=None)
@given(st.lists(st.sampled_from(["add", "delete", "merge", "rebuild"]),
                min_size=1, max_size=8),
       st.integers(0, 2 ** 31 - 1))
def test_live_mutations_preserve_rebuild_equivalence(script, seed):
    """Any interleaving of add/delete/merge_delta/epoch-rebuild keeps
    the live overlay bit-identical to a fresh re-layout of the net
    corpus under the CURRENT centroids, on the per-probe AND fused
    kernel paths."""
    from repro.core import policies, search
    from repro.index import LiveIndex, Rebuilder
    docs, index = _tiny_live_substrate(seed)
    rng = np.random.default_rng(seed)
    live = LiveIndex(index, delta_cap=256)
    epoch0 = live.epoch
    rebuilds = 0
    for op in script:
        if op == "add" and len(live.delta) < 100:
            m = int(rng.integers(1, 9))
            src = rng.integers(0, len(docs), m)
            live.add(docs[src]
                     + rng.normal(scale=0.1, size=(m, 8))
                     .astype(np.float32))
        elif op == "delete":
            pool = [i for i in range(live.next_id)
                    if i not in live.tombs]
            if pool:
                live.delete(rng.choice(pool,
                                       min(4, len(pool)), replace=False))
        elif op == "merge":
            live.merge_delta()
        elif op == "rebuild":
            # in-memory re-clustering: writes are quiesced across the
            # synchronous run_once, so no WAL is needed
            rb = Rebuilder(live, n_iters=2)
            rb.run_once("property")
            live = rb.live
            rebuilds += 1
    assert live.epoch == epoch0 + rebuilds
    queries = jnp.asarray(
        rng.normal(size=(8, 8)).astype(np.float32))
    pol = policies.patience(6, delta=2, phi=80.0, k=5, tau=3)
    equivalent = live.rebuild_equivalent()
    for kw in ({}, {"use_fused_kernel": True, "chunk": 4}):
        a = live.search(queries, pol, **kw)
        b = search(equivalent, queries, pol, **kw)
        np.testing.assert_array_equal(np.asarray(a.topk_ids),
                                      np.asarray(b.topk_ids))
        np.testing.assert_array_equal(np.asarray(a.probes),
                                      np.asarray(b.probes))
        np.testing.assert_allclose(np.asarray(a.phi_hist),
                                   np.asarray(b.phi_hist), atol=1e-4)
    # live doc count bookkeeping survives the interleaving
    assert live.n_live == len(live.net_corpus()[1])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_gbdt_predictions_bounded_by_leaves(seed):
    """Margins are sums of leaf values: finite, and constant inputs give
    constant predictions."""
    from repro.trees.gbdt import GBDT
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (300, 4)).astype(np.float32)
    y = rng.normal(0, 1, 300)
    m = GBDT("l2", n_trees=5, max_depth=3)
    f = m.fit(x, y)
    pred = m.predict(f, x)
    assert np.isfinite(pred).all()
    const = np.full((7, 4), 0.5, np.float32)
    cp = m.predict(f, const)
    assert np.allclose(cp, cp[0])
