"""Wave-scheduled serving (beyond-paper throughput layer)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import brute_force, metrics, policies, search
from repro.core.serving import WaveScheduler

pytestmark = pytest.mark.slow   # full serve loops: ~15s total


def test_wave_scheduler_serves_everything(tiny_index, tiny_corpus):
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=4, k=10,
                       n_probe=24, delta=3, phi=90.0)
    rep = ws.serve(tiny_corpus.queries[:100])
    assert len(rep.results) == 100
    assert all(p >= 1 for p in rep.probes.values())


def test_compaction_improves_occupancy(tiny_index, tiny_corpus):
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=4, k=10,
                       n_probe=24, delta=3, phi=90.0)
    with_c = ws.serve(tiny_corpus.queries[:128], compact=True)
    without = ws.serve(tiny_corpus.queries[:128], compact=False)
    assert with_c.occupancy > without.occupancy
    assert with_c.lane_steps <= without.lane_steps


def test_wave_results_match_plain_search(tiny_index, tiny_corpus,
                                         tiny_exact):
    """Same policy, same index -> same effectiveness ballpark (wave
    chunking quantises probe counts, so compare recall not ids)."""
    q = tiny_corpus.queries[:128]
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=1, k=10,
                       n_probe=24, delta=3, phi=90.0)
    rep = ws.serve(q)
    ids = np.stack([rep.results[i] for i in range(128)])
    r_wave = metrics.r_star_at_1(ids, tiny_exact[1][:128, 0])
    res = search(tiny_index, jnp.asarray(q),
                 policies.patience(24, 3, 90.0, k=10, tau=3))
    r_plain = metrics.r_star_at_1(np.asarray(res.topk_ids),
                                  tiny_exact[1][:128, 0])
    assert abs(r_wave - r_plain) < 0.08
