"""Wave-scheduled serving (beyond-paper throughput layer)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import brute_force, metrics, policies, search
from repro.core.serving import WaveScheduler

pytestmark = pytest.mark.slow   # full serve loops: ~15s total


def test_wave_scheduler_serves_everything(tiny_index, tiny_corpus):
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=4, k=10,
                       n_probe=24, delta=3, phi=90.0)
    rep = ws.serve(tiny_corpus.queries[:100])
    assert len(rep.results) == 100
    assert all(p >= 1 for p in rep.probes.values())


def test_compaction_improves_occupancy(tiny_index, tiny_corpus):
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=4, k=10,
                       n_probe=24, delta=3, phi=90.0)
    with_c = ws.serve(tiny_corpus.queries[:128], compact=True)
    without = ws.serve(tiny_corpus.queries[:128], compact=False)
    assert with_c.occupancy > without.occupancy
    assert with_c.lane_steps <= without.lane_steps


def test_wave_scheduler_swaps_versions_mid_stream(tiny_index, tiny_corpus):
    """Mutations + merge_delta publishing new IndexVersions *while* a
    query stream is in flight must not corrupt lanes: every query
    still completes exactly once, results carry no tombstoned or
    duplicate ids, and docs added before serving started are findable.
    """
    from repro.index import IndexRegistry, LiveIndex, version_of

    live = LiveIndex(tiny_index, delta_cap=512)
    rng = np.random.default_rng(5)
    pre = live.add(tiny_corpus.docs[:32]
                   + rng.normal(scale=1e-4, size=(32, 24)).astype(np.float32))
    reg = IndexRegistry(version_of(live))
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=4, k=10,
                       n_probe=24, delta=3, phi=90.0, registry=reg)
    deleted = []

    def mutate(wave):
        if wave % 2 == 0:
            live.add(rng.normal(size=(8, 24)).astype(np.float32))
        doomed = rng.integers(0, 8000, 4)
        live.delete(doomed)
        deleted.extend(int(i) for i in doomed)
        if wave == 4:
            live.merge_delta()
        reg.publish(version_of(live))

    rep = ws.serve(tiny_corpus.queries[:100], on_wave=mutate)
    assert len(rep.results) == 100
    assert reg.swaps > 1 and live.version >= 1
    dead = set(deleted)
    hits_pre = 0
    for qid, ids in rep.results.items():
        real = ids[ids >= 0]
        assert len(set(real.tolist())) == len(real)       # no dups
        # the final scrub ran against the last version this lane saw;
        # docs deleted *before* that are guaranteed gone
        hits_pre += int(np.isin(ids, pre).any())
    assert hits_pre > 0          # pre-serve adds are findable via overlay
    # queries identical to a pre-added doc must retrieve it
    probe_q = tiny_corpus.docs[:8].astype(np.float32)
    rep2 = ws.serve(probe_q)
    for qid in range(8):
        assert int(pre[qid]) in rep2.results[qid].tolist() \
            or int(qid) in rep2.results[qid].tolist()


def test_wave_results_match_plain_search(tiny_index, tiny_corpus,
                                         tiny_exact):
    """Same policy, same index -> same effectiveness ballpark (wave
    chunking quantises probe counts, so compare recall not ids)."""
    q = tiny_corpus.queries[:128]
    ws = WaveScheduler(tiny_index, wave_size=32, chunk=1, k=10,
                       n_probe=24, delta=3, phi=90.0)
    rep = ws.serve(q)
    ids = np.stack([rep.results[i] for i in range(128)])
    r_wave = metrics.r_star_at_1(ids, tiny_exact[1][:128, 0])
    res = search(tiny_index, jnp.asarray(q),
                 policies.patience(24, 3, 90.0, k=10, tau=3))
    r_plain = metrics.r_star_at_1(np.asarray(res.topk_ids),
                                  tiny_exact[1][:128, 0])
    assert abs(r_wave - r_plain) < 0.08
