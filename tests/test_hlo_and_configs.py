"""HLO collective parser + config registry invariants."""
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, reduced, shape_for
from repro.launch.hlo_analysis import (parse_collectives, roofline_terms,
                                       _shape_bytes)


def test_parse_collectives_basic():
    txt = """
  %ag = bf16[2048,512]{1,0} all-gather(%p0), replica_groups={...}
  %ar = f32[128]{0} all-reduce(%x), to_apply=%add
  %ignored = f32[4]{0} add(%a, %b)
  %agd = bf16[64]{0} all-gather-done(%ags)
  %rs = f32[256,16]{1,0} reduce-scatter(%y), dimensions={0}
"""
    st = parse_collectives(txt)
    assert st.bytes_by_kind["all-gather"] == 2048 * 512 * 2
    assert st.bytes_by_kind["all-reduce"] == 128 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 256 * 16 * 4
    assert st.count_by_kind["all-gather"] == 1   # -done not re-counted


def test_parse_tuple_all_reduce():
    txt = ("  %t = (f32[8]{0}, bf16[16]{0}) all-reduce(%a, %b), "
           "to_apply=%add\n")
    st = parse_collectives(txt)
    assert st.bytes_by_kind["all-reduce"] == 8 * 4 + 16 * 2


def test_roofline_terms_bottleneck():
    t = roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0)
    assert t["bottleneck"] == "compute_s"
    assert t["compute_s"] == pytest.approx(1.0)
    t = roofline_terms(flops=0, hbm_bytes=819e9, coll_bytes=1e9)
    assert t["bottleneck"] == "memory_s"


def test_shape_bytes():
    assert _shape_bytes("bf16", "4,4") == 32
    assert _shape_bytes("pred", "10") == 10
    assert _shape_bytes("f32", "") == 4     # scalar


# --- configs -----------------------------------------------------------------

def test_registry_covers_assignment():
    archs = list_archs()
    for a in ["minicpm3-4b", "qwen1.5-32b", "starcoder2-3b",
              "deepseek-moe-16b", "dbrx-132b", "gat-cora", "deepfm",
              "dcn-v2", "two-tower-retrieval", "xdeepfm", "msmarco-ivf"]:
        assert a in archs


def test_assigned_cell_count():
    """5 LM x 4 + 1 GNN x 4 + 4 recsys x 4 = 40 assigned cells."""
    n = 0
    for a in list_archs():
        spec = get_arch(a)
        if spec.family != "ivf":
            n += len(spec.shapes)
    assert n == 40


@pytest.mark.parametrize("arch,expect_b", [
    ("dbrx-132b", 131.6), ("deepseek-moe-16b", 16.4),
    ("minicpm3-4b", 4.1), ("qwen1.5-32b", 35.2),
    ("starcoder2-3b", 3.0)])
def test_param_counts(arch, expect_b):
    got = get_arch(arch).model.param_count() / 1e9
    assert got == pytest.approx(expect_b, rel=0.05)


def test_reduced_configs_are_small():
    for a in list_archs():
        r = reduced(get_arch(a))
        if r.family == "lm":
            assert r.model.param_count() < 5e6
        if r.family == "ivf":
            assert r.model.n_docs <= 10_000


def test_shape_lookup_errors():
    spec = get_arch("gat-cora")
    with pytest.raises(KeyError):
        shape_for(spec, "nope")
