"""Checkpoint manager: roundtrip, atomicity, GC, async, fault-restart."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import lm_batcher
from repro.runtime.fault import FaultTolerantTrainer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (16, 8)),
            "opt": {"m": jnp.zeros((16, 8)), "step": jnp.asarray(3)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    s = _state()
    mgr.save(5, s)
    step, restored = mgr.restore(jax.tree.map(np.zeros_like, s))
    assert step == 5
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    s = _state(1)
    mgr.save(1, s)
    mgr.wait()
    step, _ = mgr.restore(s)
    assert step == 1


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for i in range(5):
        mgr.save(i, _state())
    assert mgr.all_steps() == [3, 4]


def test_restores_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(1))
    mgr.save(7, _state(7))
    step, restored = mgr.restore(_state())
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(_state(7)["w"]))


def test_no_partial_checkpoint_visible(tmp_path):
    """.tmp dirs are never listed as restorable steps."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert mgr.all_steps() == []


def _make_trainer(ckpt_dir, seed=0):
    @jax.jit
    def step_fn(state, batch):
        w, s = state
        g = jnp.mean(jnp.asarray(batch["tokens"], jnp.float32)) * 0.01
        w = w - 0.1 * (w - g)
        return (w, s + 1), jnp.sum(w ** 2)

    state = (jnp.ones((4, 4)), jnp.asarray(0))
    batcher = lm_batcher(vocab=100, batch=2, seq=8, seed=seed)
    return FaultTolerantTrainer(step_fn, state, batcher,
                                CheckpointManager(ckpt_dir, keep=3,
                                                  async_save=False),
                                ckpt_every=5)


def test_fault_restart_is_deterministic(tmp_path):
    """Loss trajectory after crash+restore == uninterrupted run."""
    ref = _make_trainer(str(tmp_path / "a")).run(20)
    faulty = _make_trainer(str(tmp_path / "b")).run(
        20, fail_at={7: 1, 13: 2})
    assert faulty.restarts == 3
    np.testing.assert_allclose(ref.losses, faulty.losses, rtol=1e-6)


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written once restores onto any device layout (here:
    1 device, trivially) with values intact — the resharding API."""
    from repro.runtime.elastic import elastic_restore, remesh
    from jax.sharding import PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    s = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(3, s)
    mesh = remesh(1, model_parallel=1)
    step, restored = elastic_restore(mgr, s, mesh, {"w": P(None, None)})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(s["w"]))
