"""Optimizers, schedules, data pipeline determinism, serving waves,
straggler policy."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import (DeterministicBatcher, Prefetcher,
                                 lm_batcher, pair_batcher)
from repro.optim.optimizers import (adafactor, adamw, sgdm, warmup_cosine)
from repro.runtime.straggler import run_waves


def _quadratic(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for i in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(grads, state, params, jnp.asarray(i))
    return float(jnp.max(jnp.abs(params["w"] - target)))


def test_adamw_converges():
    assert _quadratic(adamw(0.1, weight_decay=0.0)) < 0.15


def test_adafactor_converges():
    assert _quadratic(adafactor(0.3), steps=120) < 0.3


def test_sgdm_converges():
    assert _quadratic(sgdm(0.02), steps=120) < 0.1


def test_adamw_matches_reference_math():
    """One AdamW step vs hand computation."""
    opt = adamw(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                max_grad_norm=1e9)
    p = {"w": jnp.asarray([2.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.5])}
    new_p, _ = opt.update(g, s, p, jnp.asarray(0))
    m = 0.1 * 0.5 / (1 - 0.9)
    v = 0.01 * 0.25 / (1 - 0.99)
    expected = 2.0 - 0.1 * (m / (np.sqrt(v) + 1e-8))
    np.testing.assert_allclose(float(new_p["w"][0]), expected, rtol=1e-5)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, warmup=10, total=100, floor=0.1)
    vals = [float(fn(jnp.asarray(s))) for s in [0, 9, 10, 50, 99]]
    assert vals[0] < vals[1] <= 1.0 + 1e-6
    assert vals[2] == pytest.approx(1.0, abs=0.1)
    assert vals[-1] == pytest.approx(0.1, abs=0.05)
    assert vals[3] < vals[2]


def test_adafactor_memory_is_factored():
    opt = adafactor(0.01)
    p = {"w": jnp.zeros((64, 32))}
    s = opt.init(p)
    n_state = sum(np.prod(l.shape) for l in jax.tree.leaves(s))
    assert n_state == 64 + 32          # vs 2*64*32 for adam


def test_batcher_determinism():
    b = lm_batcher(1000, 4, 16, seed=3)
    a1 = b.batch(7)
    a2 = b.batch(7)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    assert not np.array_equal(b.batch(8)["tokens"], a1["tokens"])


def test_prefetcher_yields_in_order():
    b = lm_batcher(100, 2, 4, seed=0)
    pf = Prefetcher(b, start_step=5, depth=2)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_pair_batcher_labels_consistent():
    docs = np.random.default_rng(0).normal(0, 1, (50, 8)) \
        .astype(np.float32)
    b = pair_batcher(docs, batch=16, seed=0)
    bt = b.batch(0)
    np.testing.assert_allclose(bt["doc"], docs[bt["doc_id"]])


def test_straggler_redispatch_bounds_p99():
    def lat(rng, shard):
        # shard 0 is a straggler 30% of the time
        if shard == 0 and rng.random() < 0.3:
            return 500.0
        return float(rng.uniform(5, 20))

    with_rd = run_waves(2000, 8, lat, deadline_ms=50, wave_size=32,
                        seed=0)
    assert with_rd.completed == 2000
    assert with_rd.redispatches > 0
    assert with_rd.p99_ms < 500.0      # straggler latency never surfaces


def test_straggler_pending_surfaced_at_max_waves():
    """Queries still unserved when max_waves runs out must show up in
    WaveStats.pending instead of silently vanishing."""
    def never(rng, shard):
        return 1e9                          # every shard always misses

    st = run_waves(64, 4, never, deadline_ms=50, wave_size=8, seed=0,
                   max_waves=5)
    assert st.completed == 0
    assert st.pending == 64                 # nothing lost, all surfaced
    assert st.waves == 5

    def sometimes(rng, shard):
        return 1e9 if shard == 0 else 10.0

    st2 = run_waves(64, 4, sometimes, deadline_ms=50, wave_size=4,
                    seed=0, max_waves=2)
    assert st2.completed + st2.pending == 64
    assert st2.pending > 0
