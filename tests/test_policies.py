"""Early-exit policy semantics (paper §2)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import metrics, policies, search
from repro.core.training import train_policy_models, choose_n_probe


def test_patience_exits_early(tiny_index, tiny_corpus):
    q = jnp.asarray(tiny_corpus.queries)
    res_f = search(tiny_index, q, policies.fixed(32, k=10, tau=3))
    res_p = search(tiny_index, q,
                   policies.patience(32, delta=3, phi=90.0, k=10, tau=3))
    assert np.asarray(res_p.probes).mean() < \
        np.asarray(res_f.probes).mean()
    assert (np.asarray(res_p.probes) >= 1).all()
    assert (np.asarray(res_p.probes) <= 32).all()


def test_patience_delta_monotone(tiny_index, tiny_corpus):
    """Larger patience -> more probes -> recall never degrades much."""
    q = jnp.asarray(tiny_corpus.queries)
    probes = []
    for delta in (2, 5, 12):
        res = search(tiny_index, q,
                     policies.patience(32, delta=delta, phi=90.0, k=10,
                                       tau=3))
        probes.append(float(np.asarray(res.probes).mean()))
    assert probes[0] <= probes[1] <= probes[2]


def test_infinite_patience_equals_fixed(tiny_index, tiny_corpus):
    q = jnp.asarray(tiny_corpus.queries[:64])
    res_f = search(tiny_index, q, policies.fixed(16, k=10, tau=3))
    res_p = search(tiny_index, q,
                   policies.patience(16, delta=99, phi=100.0, k=10,
                                     tau=3))
    assert (np.asarray(res_f.topk_ids) == np.asarray(res_p.topk_ids)).all()
    assert (np.asarray(res_p.probes) == 16).all()


@pytest.fixture(scope="module")
def trained_models(tiny_index, tiny_corpus):
    qs = tiny_corpus.queries
    return train_policy_models(
        tiny_index, tiny_corpus.docs, qs[:128], qs[128:192],
        n_probe=24, k=10, tau=3, n_trees=10, max_depth=3)


def test_reg_policy_runs(tiny_index, tiny_corpus, trained_models,
                         tiny_exact):
    q = jnp.asarray(tiny_corpus.queries[192:])
    pol = policies.regression(24, trained_models.reg,
                              with_intersections=False, k=10, tau=3)
    res = search(tiny_index, q, pol)
    probes = np.asarray(res.probes)
    assert (probes >= 3).all() and (probes <= 24).all()
    r = metrics.r_star_at_1(np.asarray(res.topk_ids),
                            tiny_exact[1][192:, 0])
    assert r > 0.5


def test_classifier_and_cascades(tiny_index, tiny_corpus, trained_models):
    q = jnp.asarray(tiny_corpus.queries[192:])
    pols = {
        "clf": policies.classifier(24, trained_models.clf_weighted,
                                   k=10, tau=3),
        "casc_pat": policies.cascade_patience(
            24, trained_models.clf_weighted, delta=3, phi=90.0, k=10,
            tau=3),
        "casc_reg": policies.cascade_regression(
            24, trained_models.clf_weighted, trained_models.reg_int,
            k=10, tau=3),
    }
    probes = {}
    for name, pol in pols.items():
        res = search(tiny_index, q, pol)
        p = np.asarray(res.probes)
        assert (p >= 3).all() and (p <= 24).all(), name
        probes[name] = p.mean()
    # cascades must not be slower than the pure classifier
    assert probes["casc_pat"] <= probes["clf"] + 1e-9


def test_choose_n_probe(tiny_index, tiny_corpus):
    n = choose_n_probe(tiny_index, tiny_corpus.docs,
                       tiny_corpus.queries[:128], rho=0.9, k=10,
                       n_max=64)
    assert 1 <= n <= 64
