"""End-to-end driver: train a dual-encoder retriever contrastively,
embed the corpus, build the IVF index, and serve with adaptive early
exit — the full life cycle of the paper's system.

    PYTHONPATH=src python examples/train_retriever.py [--steps 300]
    PYTHONPATH=src python examples/train_retriever.py --big   # ~100M

Training checkpoints land in /tmp/repro_retriever (restart-safe: rerun
the command after a crash and it resumes).
"""
import argparse
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core import build_index, brute_force, metrics, policies, search
from repro.core.training import choose_n_probe
from repro.data.pipeline import pair_batcher
from repro.data.synthetic import clustered_corpus
from repro.models.layers import dense, dense_init
from repro.optim.optimizers import adamw, warmup_cosine
from repro.runtime.fault import FaultTolerantTrainer


def encoder_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": dense_init(ks[i], dims[i], dims[i + 1], bias=True)
            for i in range(len(dims) - 1)}


def encode(params, x):
    n = len(params)
    for i in range(n):
        x = dense(params[f"l{i}"], x, dtype=jnp.float32)
        if i < n - 1:
            x = jax.nn.gelu(x)
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True),
                           1e-6)


def contrastive_loss(params, batch):
    q = encode(params["q"], batch["query"])
    d = encode(params["d"], batch["doc"])
    logits = q @ d.T / 0.05
    labels = jnp.arange(q.shape[0])
    lse = jax.nn.logsumexp(logits, axis=1)
    loss = jnp.mean(lse - jnp.diag(logits))
    acc = jnp.mean(jnp.argmax(logits, 1) == labels)
    return loss, acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--big", action="store_true",
                    help="~100M-param encoders (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_retriever")
    args = ap.parse_args()

    raw_dim = 256
    dims = (raw_dim, 4096, 8192, 2048, 128) if args.big else \
        (raw_dim, 512, 512, 128)
    n_params = sum((dims[i] + 1) * dims[i + 1]
                   for i in range(len(dims) - 1)) * 2
    print(f"dual encoder: {dims}, ~{n_params / 1e6:.1f}M params")

    print("corpus: 40k docs in raw feature space...")
    c = clustered_corpus(n_docs=40_000, dim=raw_dim, n_components=256,
                         n_queries=1024, spread=0.3, seed=0)

    key = jax.random.PRNGKey(0)
    params = {"q": encoder_init(jax.random.fold_in(key, 0), dims),
              "d": encoder_init(jax.random.fold_in(key, 1), dims)}
    opt = adamw(warmup_cosine(3e-4, 50, args.steps))

    @jax.jit
    def step_fn(state, batch):
        params, opt_state, i = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, acc), grads = jax.value_and_grad(
            contrastive_loss, has_aux=True)(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return (params, opt_state, i + 1), loss

    batcher = pair_batcher(c.docs, batch=128, noise=0.08, seed=0)
    trainer = FaultTolerantTrainer(
        step_fn, (params, opt.init(params), jnp.zeros((), jnp.int32)),
        batcher, CheckpointManager(args.ckpt, keep=2), ckpt_every=50)
    t0 = time.time()
    rep = trainer.run(args.steps)
    print(f"trained {rep.steps_run} steps in {rep.wall_s:.0f}s "
          f"(loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}, "
          f"restarts={rep.restarts})")
    _, (params, _, _) = trainer.ckpt.restore(
        (params, opt.init(params), jnp.zeros((), jnp.int32)))

    print("embedding corpus + building IVF index...")
    emb_docs = np.asarray(jax.jit(
        functools.partial(encode))(params["d"], jnp.asarray(c.docs)))
    emb_q = np.asarray(encode(params["q"], jnp.asarray(c.queries)))
    index = build_index(emb_docs, 256, list_pad=256, n_iters=6)

    n = choose_n_probe(index, emb_docs, emb_q[:256], rho=0.95, k=50,
                       n_max=256)
    print(f"N for R*@1>=0.95: {n}")
    _, exact = brute_force(jnp.asarray(emb_docs), jnp.asarray(emb_q), 50)
    exact = np.asarray(exact)
    for pol in (policies.fixed(n, k=50, tau=5),
                policies.patience(n, delta=4, phi=95.0, k=50, tau=5)):
        res = search(index, jnp.asarray(emb_q), pol)
        ids, probes = np.asarray(res.topk_ids), np.asarray(res.probes)
        print(f"  {pol.name:12s} R*@1={metrics.r_star_at_1(ids, exact[:, 0]):.3f} "
              f"R@50={metrics.recall_at_k(ids, c.relevant):.3f} "
              f"C={probes.mean():5.1f}")
    print(f"total {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
