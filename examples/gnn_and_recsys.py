"""Assigned-architecture tour: GAT full-graph + sampled minibatch, and a
recsys CTR model, all through the public config registry.

    PYTHONPATH=src python examples/gnn_and_recsys.py
"""
import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.data.graph_sampler import CSRGraph, block_shapes, pad_block, \
    sample_blocks
from repro.data.synthetic import click_log, random_graph
from repro.models import gnn, recsys
from repro.optim.optimizers import adamw


def gat_demo():
    cfg = dataclasses.replace(reduced(get_arch("gat-cora")).model,
                              d_in=32, n_classes=5)
    g_np = random_graph(400, 2000, 32, 5, seed=0)
    graph = gnn.Graph(jnp.asarray(g_np["feat"]),
                      jnp.asarray(g_np["edge_src"]),
                      jnp.asarray(g_np["edge_dst"]),
                      jnp.asarray(g_np["label"]))
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(0.02, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i):
        (loss, m), grads = jax.value_and_grad(
            functools.partial(gnn.loss_fn, cfg), has_aux=True)(p, graph)
        p, s = opt.update(grads, s, p, i)
        return p, s, loss, m["acc"]

    for i in range(100):
        params, state, loss, acc = step(params, state, jnp.asarray(i))
    print(f"GAT full-graph: loss={float(loss):.3f} acc={float(acc):.2f}")

    # sampled-minibatch path (the minibatch_lg cell's machinery)
    csr = CSRGraph.from_edges(g_np["edge_src"], g_np["edge_dst"], 400)
    rng = np.random.default_rng(0)
    seeds = rng.choice(400, 32, replace=False)
    blocks = sample_blocks(csr, seeds, (5, 3), rng)
    shapes = block_shapes(32, (5, 3))
    padded = [pad_block(b, e, n) for b, (e, n, _) in zip(blocks, shapes)]
    feats = jnp.asarray(g_np["feat"])[jnp.asarray(padded[-1].nodes)]
    bl = [{"edge_src": jnp.asarray(b.edge_src),
           "edge_dst": jnp.asarray(b.edge_dst),
           "edge_mask": jnp.asarray(b.edge_mask)} for b in padded]
    out = gnn.forward_blocks(cfg, params, feats, bl,
                             tuple(o for (_, _, o) in shapes))
    print(f"GAT minibatch block forward: {out.shape} (fanout 5-3)")


def recsys_demo():
    cfg = reduced(get_arch("dcn-v2")).model
    params = recsys.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-2)
    state = opt.init(params)

    @jax.jit
    def step(p, s, batch, i):
        (loss, _), grads = jax.value_and_grad(
            functools.partial(recsys.loss_fn, cfg), has_aux=True
        )(p, batch)
        p, s = opt.update(grads, s, p, i)
        return p, s, loss

    losses = []
    for i in range(50):
        data = click_log(256, cfg.n_dense, cfg.n_sparse,
                         cfg.rows_per_field, seed=i)
        batch = {k: jnp.asarray(v) for k, v in data.items()}
        params, state, loss = step(params, state, batch, jnp.asarray(i))
        losses.append(float(loss))
    print(f"DCN-v2 CTR: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    gat_demo()
    recsys_demo()
