"""Fault-tolerant LM training drill: a reduced assigned-architecture LM
trains with async checkpoints while failures are injected; the loss
trajectory is bitwise identical to an uninterrupted run.

    PYTHONPATH=src python examples/lm_fault_tolerant.py --arch dbrx-132b
"""
import argparse
import shutil

import numpy as np

from repro.launch.train import build_trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    base = f"/tmp/repro_ft_{args.arch}"
    for sub in ("a", "b"):
        shutil.rmtree(f"{base}/{sub}", ignore_errors=True)

    print(f"reference run ({args.arch} reduced, {args.steps} steps)...")
    ref = build_trainer(args.arch, smoke=True, ckpt_dir=f"{base}/a",
                        ckpt_every=5).run(args.steps)
    print(f"  losses: {ref.losses[0]:.4f} ... {ref.losses[-1]:.4f}")

    print("chaos run: injected failures at steps 7 and 13...")
    chaos = build_trainer(args.arch, smoke=True, ckpt_dir=f"{base}/b",
                          ckpt_every=5).run(
        args.steps, fail_at={7: 1, 13: 1})
    print(f"  restarts: {chaos.restarts}")
    np.testing.assert_allclose(ref.losses, chaos.losses, rtol=1e-6)
    print("  loss trajectory identical after restarts — "
          "checkpoint/restart + deterministic data replay verified.")


if __name__ == "__main__":
    main()
