"""Serving scenario (the paper's own kind of system): batched query
streams against an IVF index, cascade early-exit policy, wave-scheduler
compaction, straggler-tolerant waves.

    PYTHONPATH=src python examples/serve_early_exit.py
"""
import time

import numpy as np

import jax.numpy as jnp

from repro.core import brute_force, build_index, metrics, policies, search
from repro.core.serving import WaveScheduler
from repro.core.training import train_policy_models, choose_n_probe
from repro.data.synthetic import clustered_corpus


def main():
    k, tau = 50, 5
    print("corpus + index...")
    c = clustered_corpus(n_docs=50_000, dim=64, n_components=384,
                         n_queries=2048, spread=0.3, seed=1)
    index = build_index(c.docs, 384, list_pad=256, n_iters=6)
    train_q, valid_q, test_q = (c.queries[:768], c.queries[768:1024],
                                c.queries[1024:])
    n = choose_n_probe(index, c.docs, valid_q, rho=0.95, k=k, n_max=384)
    print(f"N (R*@1>=0.95) = {n}")

    print("training Exit/Continue classifier + REG (GBDT + SMOTE)...")
    pm = train_policy_models(index, c.docs, train_q, valid_q, n_probe=n,
                             k=k, tau=tau, exit_weight=3.0, n_trees=40,
                             max_depth=5)

    _, exact = brute_force(jnp.asarray(c.docs), jnp.asarray(test_q), k)
    exact = np.asarray(exact)
    print("\npolicy comparison on the test stream:")
    for pol in (policies.fixed(n, k=k, tau=tau),
                policies.patience(n, 4, 95.0, k=k, tau=tau),
                policies.cascade_patience(n, pm.clf_weighted, 4, 95.0,
                                          k=k, tau=tau)):
        res = search(index, jnp.asarray(test_q), pol)
        ids, probes = np.asarray(res.topk_ids), np.asarray(res.probes)
        print(f"  {pol.name:20s} R*@1="
              f"{metrics.r_star_at_1(ids, exact[:, 0]):.3f} "
              f"mRR@10={metrics.mrr_at_10(ids, c.relevant[1024:]):.3f} "
              f"C={probes.mean():5.1f}")

    print("\nwave-scheduled serving (batched requests, compaction):")
    ws = WaveScheduler(index, wave_size=128, chunk=4, k=k, n_probe=n,
                       delta=4, phi=95.0)
    for compact in (False, True):
        t0 = time.time()
        rep = ws.serve(test_q, compact=compact)
        print(f"  compact={compact!s:5s} occupancy={rep.occupancy:.2f} "
              f"waves={rep.waves} lane_steps/q="
              f"{rep.lane_steps / len(test_q):5.1f} "
              f"wall={time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
