"""Quickstart: build an IVF index, search with early exit, compare to
brute force.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import (brute_force, build_index, metrics, policies,
                        search)
from repro.data.synthetic import clustered_corpus


def main():
    print("generating corpus (30k docs, 64-d)...")
    c = clustered_corpus(n_docs=30_000, dim=64, n_components=256,
                         n_queries=512, seed=0)
    print("building IVF index (256 clusters)...")
    index = build_index(c.docs, 256, list_pad=256, n_iters=6)

    queries = jnp.asarray(c.queries)
    _, exact = brute_force(jnp.asarray(c.docs), queries, 10)
    exact = np.asarray(exact)

    for pol in (policies.fixed(48, k=10, tau=5),
                policies.patience(48, delta=4, phi=95.0, k=10, tau=5)):
        res = search(index, queries, pol)
        ids = np.asarray(res.topk_ids)
        probes = np.asarray(res.probes)
        print(f"{pol.name:12s} R*@1={metrics.r_star_at_1(ids, exact[:, 0]):.3f} "
              f"mean probes={probes.mean():5.1f} "
              f"(max {probes.max()})")
    print("patience reaches near-fixed recall with a fraction of the "
          "probes — the paper's core claim.")


if __name__ == "__main__":
    main()
