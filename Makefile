PY := python
export PYTHONPATH := src

.PHONY: test test-fast bench bench-smoke chaos-smoke

test:
	$(PY) -m pytest -q

# skip the long distributed/serving tests (marked @pytest.mark.slow)
test-fast:
	$(PY) -m pytest -q -m "not slow"

bench:
	$(PY) -m benchmarks.run

# minutes-scale benchmark pass (CI): tiny substrate, then assert every
# JSON artifact parses and BENCH_kernels.json carries the pipelined /
# packed-sort / chunk-x-blk_l sweep schema
bench-smoke:
	$(PY) -m benchmarks.run --smoke
	$(PY) -c "import json; \
	  [json.load(open('artifacts/BENCH_' + n + '.json')) \
	   for n in ('table2', 'serving')]; \
	  d = json.load(open('artifacts/BENCH_kernels.json')); \
	  assert {'rows', 'fused_sweep', 'sort', 'backend'} <= d.keys(); \
	  assert d['fused_sweep'], 'empty fused sweep'; \
	  assert all({'chunk', 'blk_l', 'us', 'pipelined', 'delta'} \
	             <= r.keys() for r in d['fused_sweep']); \
	  assert any(r['delta'] for r in d['fused_sweep']), \
	         'no in-kernel-delta row'; \
	  s = d['sort']; \
	  assert s['packed_us'] > 0 and s['tagged_us'] > 0; \
	  assert any(r['us'] is None for r in d['rows']) \
	         or d['pipelined_available'], 'pipelined row missing'; \
	  sv = json.load(open('artifacts/BENCH_serving.json')); \
	  gap = sv['live_stream']['recall_gap']; \
	  assert gap <= 0.01, f'live-stream recall gap {gap} > 1%'; \
	  r = json.load(open('artifacts/BENCH_resilience.json')); \
	  rb = r['rebuild']; \
	  assert {'crash_boundaries', 'swap_race', 'drift'} <= rb.keys(); \
	  assert all({'failpoint', 'resolution', 'bit_identical', \
	              'recovery_ms'} <= b.keys() \
	             for b in rb['crash_boundaries']); \
	  assert {'fenced', 'lost_mutations', 'recovered_bit_identical'} \
	         <= rb['swap_race'].keys(); \
	  assert {'recall_fixed', 'recall_rebuilt', 'rebuilds_triggered', \
	          'recall_restored'} <= rb['drift'].keys(); \
	  print('bench artifacts OK')"

# seeded chaos drills on a tiny substrate: crash + WAL recovery must be
# bit-identical (including at every rebuild boundary), the rebuild
# swap race must be epoch-fenced, and the drift drill must show the
# rebuild restoring recall
chaos-smoke:
	$(PY) -m repro.launch.serve --chaos --n-docs 4000 --queries 64 \
	  --clusters 32 --dim 24 --n-probe 16 --k 10
	$(PY) -c "import json; \
	  d = json.load(open('artifacts/BENCH_resilience.json')); \
	  assert d['recovery']['bit_identical'], 'recovery not bit-identical'; \
	  assert d['recovery']['crashes'] > 0, 'no crashes injected'; \
	  assert len(d['deadline_curve']) > 0, 'empty deadline curve'; \
	  assert d['shard_faults']['attempts'] > 0, 'shard drill did not run'; \
	  rb = d['rebuild']; \
	  bs = rb['crash_boundaries']; \
	  assert len(bs) == 6, 'rebuild boundaries missing'; \
	  assert all(b['bit_identical'] for b in bs), \
	         'rebuild-crash recovery not bit-identical'; \
	  assert {'aborted', 'committed'} \
	         == {b['resolution'] for b in bs}, 'both windows required'; \
	  sr = rb['swap_race']; \
	  assert sr['fenced'] and sr['lost_mutations'] == 0 \
	         and sr['recovered_bit_identical'], 'swap race not fenced'; \
	  dr = rb['drift']; \
	  assert dr['rebuilds_triggered'] > 0 and dr['recall_restored'], \
	         'drift rebuild did not restore recall'; \
	  print('chaos artifact OK')"
