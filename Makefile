PY := python
export PYTHONPATH := src

.PHONY: test test-fast bench bench-smoke

test:
	$(PY) -m pytest -q

# skip the long distributed/serving tests (marked @pytest.mark.slow)
test-fast:
	$(PY) -m pytest -q -m "not slow"

bench:
	$(PY) -m benchmarks.run

# minutes-scale benchmark pass (CI): tiny substrate, then assert every
# JSON artifact parses
bench-smoke:
	$(PY) -m benchmarks.run --smoke
	$(PY) -c "import json; \
	  [json.load(open('artifacts/BENCH_' + n + '.json')) \
	   for n in ('kernels', 'table2', 'serving')]; \
	  print('bench artifacts OK')"
