PY := python
export PYTHONPATH := src

.PHONY: test test-fast bench

test:
	$(PY) -m pytest -q

# skip the long distributed/serving tests (marked @pytest.mark.slow)
test-fast:
	$(PY) -m pytest -q -m "not slow"

bench:
	$(PY) -m benchmarks.run
