"""Sharded, async, atomic checkpointing with elastic restore.

Layout (one directory per step):
    <root>/step_000123.tmp/   -> written, fsync'd, then renamed to
    <root>/step_000123/       -> atomic publish (crash-safe)
        index.json            -> pytree structure, dtypes, shapes, pspecs
        arr_000.npy ...       -> one file per leaf (global view)

Single-host containers hold the global array; on a real multi-host pod
each host writes its addressable shards (the index format already
carries the PartitionSpec for that). Restore re-shards onto *any* mesh
(elastic scaling: ``repro.runtime.elastic``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


class CheckpointError(RuntimeError):
    """A checkpoint on disk is missing, truncated, or corrupt.

    Raised instead of raw ``KeyError``/``json``/``numpy`` tracebacks so
    the message always carries the offending path and the expected
    layout (``index.json`` + one ``arr_NNNNN.npy`` per leaf)."""


def _leaf_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _read_index(path: str) -> Dict[str, Any]:
    """Parse <ckpt dir>/index.json with actionable failure modes."""
    idx_path = os.path.join(path, "index.json")
    if not os.path.isdir(path):
        raise CheckpointError(
            f"checkpoint directory {path!r} does not exist — expected a "
            f"published step dir (step_NNNNNNNN/) containing index.json "
            f"plus one arr_NNNNN.npy per leaf")
    if not os.path.exists(idx_path):
        raise CheckpointError(
            f"checkpoint {path!r} has no index.json — the directory is "
            f"incomplete (torn write? partial copy?); expected "
            f"index.json with keys 'step'/'keys'/'treedef' plus one "
            f"arr_NNNNN.npy per leaf")
    try:
        with open(idx_path) as f:
            index = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(
            f"{idx_path!r} is truncated or corrupt ({e}); the snapshot "
            f"cannot be trusted — restore an older step or delete this "
            f"directory") from e
    for key in ("step", "keys"):
        if key not in index:
            raise CheckpointError(
                f"{idx_path!r} is missing required field {key!r} — "
                f"expected schema {{'step': int, 'keys': [{{'key', "
                f"'file', 'dtype', 'shape'}}...], 'treedef': str}}")
    return index


def _load_leaf(path: str, entry: Dict[str, Any]) -> np.ndarray:
    fn = os.path.join(path, entry["file"])
    try:
        arr = np.load(fn, allow_pickle=False)
    except FileNotFoundError as e:
        raise CheckpointError(
            f"checkpoint {path!r} is missing array file "
            f"{entry['file']!r} for leaf {entry.get('key', '?')!r} "
            f"(expected dtype={entry.get('dtype')}, "
            f"shape={entry.get('shape')})") from e
    except (ValueError, EOFError, OSError) as e:
        raise CheckpointError(
            f"array file {fn!r} for leaf {entry.get('key', '?')!r} is "
            f"truncated or corrupt ({e}); expected "
            f"dtype={entry.get('dtype')}, shape={entry.get('shape')} — "
            f"restore an older step") from e
    want = entry.get("shape")
    if want is not None and list(arr.shape) != list(want):
        raise CheckpointError(
            f"array file {fn!r} for leaf {entry.get('key', '?')!r} has "
            f"shape {list(arr.shape)} but index.json recorded {want} — "
            f"the snapshot is internally inconsistent")
    return arr


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Pytree,
             pspecs: Optional[Pytree] = None) -> str:
        self.wait()
        # materialise on host *before* handing to the writer thread so
        # the training loop can donate/overwrite device buffers
        flat = _leaf_paths(tree)
        host = [(k, np.asarray(v)) for k, v in flat]
        treedef = jax.tree_util.tree_structure(tree)
        spec_strs = None
        if pspecs is not None:
            spec_strs = [str(s) for _, s in _leaf_paths(
                jax.tree.map(lambda _, s: s, tree, pspecs,
                             is_leaf=lambda x: x is None))] \
                if pspecs is not tree else None
        path = os.path.join(self.root, f"step_{step:08d}")

        def write():
            tmp = path + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            index = {"step": step, "keys": [], "treedef": str(treedef)}
            for i, (k, v) in enumerate(host):
                fn = f"arr_{i:05d}.npy"
                np.save(os.path.join(tmp, fn), v)
                index["keys"].append({"key": k, "file": fn,
                                      "dtype": str(v.dtype),
                                      "shape": list(v.shape)})
            if spec_strs:
                index["pspecs"] = spec_strs
            with open(os.path.join(tmp, "index.json"), "w") as f:
                json.dump(index, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)            # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Tuple[int, Pytree]:
        """Load into the structure of ``template``; optionally re-shard
        onto new device layout (elastic restore)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        path = os.path.join(self.root, f"step_{step:08d}")
        index = _read_index(path)
        arrays = [_load_leaf(path, e) for e in index["keys"]]
        leaves, treedef = jax.tree_util.tree_flatten(template)
        if len(leaves) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, template has "
                f"{len(leaves)}")
        if shardings is not None:
            shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, shard_leaves)]
        else:
            arrays = [jnp.asarray(a) for a in arrays]
        return step, jax.tree_util.tree_unflatten(treedef, arrays)

    def load_arrays(self, step: Optional[int] = None
                    ) -> Tuple[int, Dict[str, np.ndarray]]:
        """Load a checkpoint as ``{key: host array}`` without a pytree
        template.  Keys come from ``jax.tree_util.keystr`` at save time
        (a dict tree saves ``"['name']"``; the surrounding quoting is
        stripped so callers see plain ``name``)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        path = os.path.join(self.root, f"step_{step:08d}")
        index = _read_index(path)
        out: Dict[str, np.ndarray] = {}
        for e in index["keys"]:
            key = e["key"].strip("[]'\"")
            out[key] = _load_leaf(path, e)
        return step, out
