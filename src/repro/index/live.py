"""LiveIndex: streaming mutations over a frozen cluster-major IVF index.

Write path (host-coordinated, cheap):
  * ``add``    -> vectors land in the :class:`DeltaBuffer`, pre-assigned
                  to their nearest centroid.
  * ``delete`` -> main-index docs get their stored id burned to -1
                  (the tombstone every scan path masks); buffered docs
                  get their slot cleared.  The external id is recorded
                  in the cumulative :class:`Tombstones` set.
  * ``merge_delta`` -> background compaction: re-layout the net corpus
                  (survivors + buffered adds) into a fresh immutable
                  ``IVFIndex`` with the SAME centroids, respecting the
                  ``align`` padding contract.  Entries that would
                  overflow a full list spill back into the buffer.

Read path: ``live.search(...)`` == ``core.search(index, ..., delta=
view)``.  The key invariant (tested): the overlay view returns
bit-identical top-k, probe counts and phi history to a freshly
rebuilt index holding the net corpus, for every exit policy, on both
the per-probe and fused kernel paths.  Centroids never change under
mutation within an *epoch* (``merge_delta`` keeps them fixed), which
is what keeps probe order — and mid-flight lane state — valid across
``merge_delta`` version swaps.  Only a background re-clustering
(``repro.index.rebuild``) retrains them, bumping ``epoch`` so readers
drain in-flight lanes before adopting the new centroid generation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.ivf import (DeltaView, IVFIndex, search as core_search,
                            validate_alignment)
from repro.index.delta import (DeltaBuffer, DeltaFull, Tombstones,
                               assign_clusters)
from repro.index.wal import OP_ADD, OP_DELETE, OP_MERGE


def relayout(vecs: np.ndarray, ids: np.ndarray, assign: np.ndarray,
             centroids, *, list_pad: int, align: int = 64,
             round_total_to: Optional[int] = None) -> IVFIndex:
    """Cluster-major re-layout of an already-assigned corpus.

    Same physical format as ``build_index`` (``align``-aligned list
    offsets, ``list_pad`` slack tail) but with fixed centroids and
    caller-provided assignments — the primitive under ``merge_delta``
    and the rebuild-equivalence oracle.  The within-cluster order of
    ``vecs`` is preserved (stable sort), so ties resolve like the
    insertion order the live overlay sees.  ``round_total_to`` pads the
    total row count up to a multiple, so repeated merges reuse compiled
    search executables instead of re-tracing per merge.
    """
    if align <= 0:
        raise ValueError(f"align must be positive, got {align}")
    if list_pad % align:
        raise ValueError(
            f"list_pad={list_pad} must be a multiple of align={align}")
    vecs = np.asarray(vecs, np.float32)
    ids = np.asarray(ids, np.int32)
    assign = np.asarray(assign, np.int32)
    centroids_np = np.asarray(centroids, np.float32)
    c, d = centroids_np.shape
    sizes = np.bincount(assign, minlength=c).astype(np.int32)
    over = np.nonzero(sizes > list_pad)[0]
    if over.size:
        raise ValueError(
            f"cluster {int(over[0])} would hold {int(sizes[over[0]])} "
            f"docs > list_pad={list_pad}; spill the overflow back to "
            f"the delta buffer (merge_delta does) or rebuild offline")
    aligned = ((sizes + align - 1) // align) * align
    offsets = np.zeros(c, np.int32)
    offsets[1:] = np.cumsum(aligned)[:-1].astype(np.int32)
    total = int(aligned.sum()) + list_pad
    if round_total_to:
        total = -(-total // round_total_to) * round_total_to
    sorted_docs = np.zeros((total, d), np.float32)
    sorted_ids = np.full(total, -1, np.int32)
    order = np.argsort(assign, kind="stable")
    pos = 0
    for cid in range(c):
        sz = int(sizes[cid])
        sel = order[pos: pos + sz]
        sorted_docs[offsets[cid]: offsets[cid] + sz] = vecs[sel]
        sorted_ids[offsets[cid]: offsets[cid] + sz] = ids[sel]
        pos += sz
    return IVFIndex(jnp.asarray(centroids_np), jnp.asarray(sorted_docs),
                    jnp.asarray(sorted_ids), jnp.asarray(offsets),
                    jnp.asarray(sizes), list_pad)


class LiveIndex:
    """Mutable front over an immutable IVFIndex + delta + tombstones.

    ``wal`` (optional :class:`repro.index.wal.MutationWAL`): every
    mutation appends one fsync'd record *before* touching in-memory
    state (classic write-ahead ordering; arguments are validated first
    so a logged record can always be replayed).  Combined with
    ``IndexRegistry`` snapshots this makes the index crash-safe:
    ``IndexRegistry.recover(manager, wal)`` rebuilds a bit-identical
    LiveIndex from the latest snapshot plus log replay.
    """

    def __init__(self, index: IVFIndex, *, delta_cap: int = 1024,
                 align: int = 64, round_total_to: int = 4096, wal=None):
        validate_alignment(index, blk_l=align)
        self.index = index
        self.align = align
        self.round_total_to = round_total_to
        self._centroids = np.asarray(index.centroids)
        self._refresh_mirrors()
        self.next_id = int(self._doc_ids.max(initial=-1)) + 1
        self.delta = DeltaBuffer(index.dim, delta_cap)
        self.tombs = Tombstones(self.next_id)
        self.version = 0                 # bumped by merge_delta
        self.seq = 0                     # bumped by every mutation
        self.epoch = 0                   # bumped by a rebuild publish
        self.wal = wal
        self._replaying = False

    @classmethod
    def from_version(cls, ver, *, align: int = 64,
                     round_total_to: int = 4096, wal=None) -> "LiveIndex":
        """Rebuild a LiveIndex from a published/restored snapshot
        (``repro.index.registry.IndexVersion``).  The delta buffer and
        tombstone set are reconstructed slot-for-slot, so replaying the
        same mutations yields the same state as the original instance."""
        self = cls.__new__(cls)
        self.index = ver.index
        self.align = align
        self.round_total_to = round_total_to
        self._centroids = np.asarray(ver.index.centroids)
        self._refresh_mirrors()
        self.next_id = int(ver.next_id)
        dvecs = np.asarray(ver.delta.vecs)
        dids = np.asarray(ver.delta.ids)
        dassign = np.asarray(ver.delta.assign)
        buf = DeltaBuffer(dvecs.shape[1], dvecs.shape[0])
        buf.vecs[: dvecs.shape[0]] = dvecs
        buf.ids[: dids.shape[0]] = dids
        buf.assign[: dassign.shape[0]] = dassign
        # assign >= 0 marks every consumed slot (delete burns only the
        # id; compact_keep resets assign) -> append pointer position
        buf.count = int((dassign >= 0).sum())
        buf._slot_of = {int(i): s for s, i in enumerate(dids) if i >= 0}
        self.delta = buf
        dead = np.asarray(ver.dead)
        tombs = Tombstones(dead.shape[0])
        tombs._dead[: dead.shape[0]] = dead
        tombs.count = int(dead.sum())
        self.tombs = tombs
        self.version = int(getattr(ver, "merges", 0))
        self.seq = int(ver.seq) if getattr(ver, "seq", -1) >= 0 \
            else int(ver.version)
        self.epoch = int(getattr(ver, "epoch", 0))
        self.wal = wal
        self._replaying = False
        return self

    def _log(self, op: int, payload: Optional[np.ndarray] = None) -> None:
        if self.wal is not None and not self._replaying:
            # merge is a compaction boundary: force the group-commit
            # batch to disk so the record (and everything before it)
            # is durable before the expensive re-layout runs
            self.wal.append(op, self.seq + 1, payload,
                            force=(op == OP_MERGE))

    # -- host mirrors -------------------------------------------------------
    def _refresh_mirrors(self) -> None:
        self._doc_ids = np.asarray(self.index.doc_ids)
        self._offsets = np.asarray(self.index.cluster_offsets)
        rows = np.nonzero(self._doc_ids >= 0)[0]
        self._row_of = dict(
            zip(self._doc_ids[rows].tolist(), rows.tolist()))

    def _main_assignments(self, rows: np.ndarray) -> np.ndarray:
        """Recover row -> cluster from the layout (offsets are sorted;
        empty clusters share the next offset and own no rows)."""
        return (np.searchsorted(self._offsets, rows, side="right") - 1
                ).astype(np.int32)

    # -- mutations ----------------------------------------------------------
    @property
    def n_live(self) -> int:
        return len(self._row_of) + len(self.delta)

    def add(self, vecs: np.ndarray) -> np.ndarray:
        """Stage new vectors; returns their external doc ids.
        Raises :class:`DeltaFull` when the buffer is out of slots."""
        vecs = np.asarray(vecs, np.float32).reshape(-1, self.index.dim)
        m = vecs.shape[0]
        self.delta.ensure_room(m)        # validate BEFORE logging
        self._log(OP_ADD, vecs)
        ids = np.arange(self.next_id, self.next_id + m, dtype=np.int32)
        assign = assign_clusters(vecs, self._centroids)
        self.delta.add(vecs, ids, assign)
        self.next_id += m
        self.tombs.ensure_capacity(self.next_id)
        self.seq += 1
        return ids

    def delete(self, ids) -> None:
        """Tombstone documents by external id (idempotent)."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        bad = ids[(ids < 0) | (ids >= self.next_id)]
        if bad.size:                     # validate BEFORE logging
            raise ValueError(f"doc id {int(bad[0])} was never allocated")
        self._log(OP_DELETE, ids)
        burn_rows = []
        for i in ids:
            i = int(i)
            if i in self.tombs:
                continue
            self.tombs.add((i,))
            if not self.delta.delete(i):
                burn_rows.append(self._row_of.pop(i))
        if burn_rows:
            rows = np.asarray(burn_rows)
            self._doc_ids = self._doc_ids.copy()
            self._doc_ids[rows] = -1
            self.index = IVFIndex(
                self.index.centroids, self.index.docs,
                self.index.doc_ids.at[jnp.asarray(rows)].set(-1),
                self.index.cluster_offsets, self.index.cluster_sizes,
                self.index.list_pad)
        self.seq += 1

    def merge_delta(self) -> int:
        """Fold the delta buffer into a fresh immutable main index.

        Buffered entries are appended to their assigned cluster's list
        after the surviving docs; entries that would push a list past
        ``list_pad`` spill back into the buffer (newest first out).
        Returns the new version number.
        """
        self._log(OP_MERGE)
        lp = self.index.list_pad
        rows = np.nonzero(self._doc_ids >= 0)[0]
        assign_main = self._main_assignments(rows)
        c = self.index.n_clusters
        fill = np.bincount(assign_main, minlength=c).astype(np.int64)
        slots = self.delta.live_slots()
        take = np.ones(slots.size, bool)
        for j, s in enumerate(slots):
            cl = int(self.delta.assign[s])
            if fill[cl] >= lp:
                take[j] = False          # spill: stays buffered
            else:
                fill[cl] += 1
        merged = slots[take]
        docs_np = np.asarray(self.index.docs)
        net_vecs = np.concatenate([docs_np[rows], self.delta.vecs[merged]])
        net_ids = np.concatenate(
            [self._doc_ids[rows], self.delta.ids[merged]])
        net_assign = np.concatenate(
            [assign_main, self.delta.assign[merged]])
        self.index = relayout(net_vecs, net_ids, net_assign,
                              self._centroids, list_pad=lp,
                              align=self.align,
                              round_total_to=self.round_total_to)
        self.delta.compact_keep(slots[~take])
        self._refresh_mirrors()
        self.version += 1
        self.seq += 1
        return self.version

    # -- read path ----------------------------------------------------------
    def delta_view(self) -> DeltaView:
        return self.delta.view()

    def dead_lookup(self) -> jnp.ndarray:
        return self.tombs.lookup()

    def search(self, queries, policy, **kwargs):
        """Adaptive search over (main index + delta + tombstones)."""
        return core_search(self.index, jnp.asarray(queries), policy,
                           delta=self.delta_view(), **kwargs)

    # -- oracles (tests / offline maintenance) ------------------------------
    def net_corpus(self) -> Tuple[np.ndarray, np.ndarray]:
        """(vecs, external ids) of every live doc: main survivors in
        corpus order, then buffered adds in insertion order."""
        rows = np.nonzero(self._doc_ids >= 0)[0]
        rows = rows[np.argsort(self._doc_ids[rows], kind="stable")]
        slots = self.delta.live_slots()
        vecs = np.concatenate(
            [np.asarray(self.index.docs)[rows], self.delta.vecs[slots]])
        ids = np.concatenate([self._doc_ids[rows], self.delta.ids[slots]])
        return vecs, ids

    def rebuild_equivalent(self) -> IVFIndex:
        """Fresh from-scratch re-layout of the net corpus with the same
        centroids: the rebuild-equivalence oracle.  Searching it must be
        bit-identical to the live overlay view for every policy."""
        rows = np.nonzero(self._doc_ids >= 0)[0]
        assign_main = self._main_assignments(rows)
        slots = self.delta.live_slots()
        vecs = np.concatenate(
            [np.asarray(self.index.docs)[rows], self.delta.vecs[slots]])
        ids = np.concatenate([self._doc_ids[rows], self.delta.ids[slots]])
        assign = np.concatenate([assign_main, self.delta.assign[slots]])
        # spilled entries can push a logical cluster past list_pad (that
        # is what spilling is for); the oracle grows the tile so the
        # rebuilt index can hold them.  Extra rows are masked padding,
        # so per-probe candidate sets — and results — are unchanged.
        sizes = np.bincount(assign, minlength=self.index.n_clusters)
        biggest = int(sizes.max(initial=0))
        lp = max(self.index.list_pad,
                 -(-biggest // self.align) * self.align)
        return relayout(vecs, ids, assign, self._centroids,
                        list_pad=lp, align=self.align)
