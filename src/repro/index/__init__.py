"""Live index mutation: delta buffer, tombstones, versioned snapshots,
and the crash-safety pair (mutation WAL + snapshot recovery)."""
from repro.core.ivf import DeltaView
from repro.index.delta import (DeltaBuffer, DeltaFull, Tombstones,
                               assign_clusters)
from repro.index.live import LiveIndex, relayout
from repro.index.registry import IndexRegistry, IndexVersion, version_of
from repro.index.wal import (MutationWAL, ReplayReport, WALCorruptError,
                             WALRecord)
