"""Live index mutation: delta buffer, tombstones, versioned snapshots."""
from repro.core.ivf import DeltaView
from repro.index.delta import (DeltaBuffer, DeltaFull, Tombstones,
                               assign_clusters)
from repro.index.live import LiveIndex, relayout
from repro.index.registry import IndexRegistry, IndexVersion, version_of
