"""Live index mutation: delta buffer, tombstones, versioned snapshots,
the crash-safety pair (mutation WAL + snapshot recovery), and the
background re-clustering pipeline (two-phase rebuild publish)."""
from repro.core.ivf import DeltaView
from repro.index.delta import (DeltaBuffer, DeltaFull, Tombstones,
                               assign_clusters)
from repro.index.live import LiveIndex, relayout
from repro.index.rebuild import (DriftTracker, RebuildCrash, Rebuilder,
                                 RebuildReport, resolve_pending_rebuild)
from repro.index.registry import (IndexRegistry, IndexVersion,
                                  StaleEpochError, version_of)
from repro.index.wal import (EPOCH_OPS, MUTATION_OPS, MutationWAL,
                             OP_REBUILD_ABORT, OP_REBUILD_BEGIN,
                             OP_REBUILD_COMMIT, ReplayReport,
                             WALCorruptError, WALRecord)
