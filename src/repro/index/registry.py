"""Versioned snapshot registry: atomic publish/subscribe of index state.

``search()``/``WaveScheduler`` read an :class:`IndexVersion` (immutable
snapshot of main index + delta view + dead lookup); the mutation path
publishes a fresh one whenever state changes.  Readers pick up the new
version between waves — never mid-wave — so every in-flight probe loop
sees one coherent (index, delta, tombstones) triple.

Snapshots round-trip through ``checkpoint.CheckpointManager`` (atomic
dir-rename publish, one .npy per array), so a serving process can be
restarted from the last published version without replaying mutations.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.ivf import DeltaView, IVFIndex


@dataclass(frozen=True)
class IndexVersion:
    """One immutable, publishable snapshot of the live index."""
    version: int
    index: IVFIndex
    delta: DeltaView
    dead: jnp.ndarray          # (id_capacity,) bool tombstone lookup
    next_id: int


def version_of(live, *, version: Optional[int] = None) -> IndexVersion:
    """Snapshot a :class:`repro.index.live.LiveIndex`."""
    return IndexVersion(
        version=live.seq if version is None else version,
        index=live.index,
        delta=live.delta_view(),
        dead=live.dead_lookup(),
        next_id=live.next_id)


class IndexRegistry:
    """Thread-safe single-slot publish/subscribe for IndexVersions."""

    def __init__(self, initial: Optional[IndexVersion] = None):
        self._lock = threading.Lock()
        self._current: Optional[IndexVersion] = None
        self.swaps = 0
        if initial is not None:
            self.publish(initial)

    def publish(self, ver: IndexVersion) -> IndexVersion:
        with self._lock:
            if self._current is not None and \
                    ver.version <= self._current.version:
                ver = IndexVersion(self._current.version + 1, ver.index,
                                   ver.delta, ver.dead, ver.next_id)
            self._current = ver
            self.swaps += 1
            return ver

    def current(self) -> IndexVersion:
        with self._lock:
            if self._current is None:
                raise RuntimeError("registry holds no published version")
            return self._current

    # -- persistence ---------------------------------------------------------
    def save(self, manager) -> str:
        """Write the current version through a CheckpointManager."""
        ver = self.current()
        ix = ver.index
        tree = {
            "centroids": ix.centroids, "docs": ix.docs,
            "doc_ids": ix.doc_ids, "offsets": ix.cluster_offsets,
            "sizes": ix.cluster_sizes,
            "dvecs": ver.delta.vecs, "dids": ver.delta.ids,
            "dassign": ver.delta.assign, "dead": ver.dead,
            "meta": np.asarray(
                [ix.list_pad, ver.version, ver.next_id], np.int64),
        }
        return manager.save(ver.version, tree)

    @staticmethod
    def restore(manager, step: Optional[int] = None
                ) -> Tuple["IndexRegistry", IndexVersion]:
        step, arrs = manager.load_arrays(step)
        list_pad, version, next_id = (int(x) for x in arrs["meta"])
        ver = IndexVersion(
            version=version,
            index=IVFIndex(jnp.asarray(arrs["centroids"]),
                           jnp.asarray(arrs["docs"]),
                           jnp.asarray(arrs["doc_ids"]),
                           jnp.asarray(arrs["offsets"]),
                           jnp.asarray(arrs["sizes"]), list_pad),
            delta=DeltaView(jnp.asarray(arrs["dvecs"]),
                            jnp.asarray(arrs["dids"]),
                            jnp.asarray(arrs["dassign"])),
            dead=jnp.asarray(arrs["dead"]),
            next_id=next_id)
        return IndexRegistry(ver), ver
