"""Versioned snapshot registry: atomic publish/subscribe of index state.

``search()``/``WaveScheduler`` read an :class:`IndexVersion` (immutable
snapshot of main index + delta view + dead lookup); the mutation path
publishes a fresh one whenever state changes.  Readers pick up the new
version between waves — never mid-wave — so every in-flight probe loop
sees one coherent (index, delta, tombstones) triple.

Snapshots round-trip through ``checkpoint.CheckpointManager`` (atomic
dir-rename publish, one .npy per array).  With a mutation WAL
(``repro.index.wal``) the pair is crash-safe: ``recover()`` loads the
latest snapshot and replays every logged mutation past it, rebuilding
a LiveIndex bit-identical to the one that crashed.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointError
from repro.core.ivf import DeltaView, IVFIndex

_SNAPSHOT_KEYS = ("centroids", "docs", "doc_ids", "offsets", "sizes",
                  "dvecs", "dids", "dassign", "dead", "meta")


class StaleEpochError(RuntimeError):
    """A publish carried an epoch older than the registry's current one.

    Raised when a ``merge_delta`` (or any publisher) computed against a
    pre-rebuild index races a background rebuild's epoch-bumped
    publish: the stale version must NOT clobber the re-clustered one.
    The loser re-reads ``registry.current()`` and redoes its work
    against the new epoch (its mutations are safe — they are in the
    WAL and were replayed onto the rebuild candidate during catch-up).
    """


@dataclass(frozen=True)
class IndexVersion:
    """One immutable, publishable snapshot of the live index."""
    version: int
    index: IVFIndex
    delta: DeltaView
    dead: jnp.ndarray          # (id_capacity,) bool tombstone lookup
    next_id: int
    seq: int = -1              # LiveIndex mutation counter at snapshot
    merges: int = 0            # LiveIndex merge counter at snapshot
    epoch: int = 0             # centroid generation (bumped by rebuild)


def version_of(live, *, version: Optional[int] = None) -> IndexVersion:
    """Snapshot a :class:`repro.index.live.LiveIndex`."""
    return IndexVersion(
        version=live.seq if version is None else version,
        index=live.index,
        delta=live.delta_view(),
        dead=live.dead_lookup(),
        next_id=live.next_id,
        seq=live.seq,
        merges=live.version,
        epoch=int(getattr(live, "epoch", 0)))


class IndexRegistry:
    """Thread-safe single-slot publish/subscribe for IndexVersions."""

    def __init__(self, initial: Optional[IndexVersion] = None):
        self._lock = threading.Lock()
        self._current: Optional[IndexVersion] = None
        self.swaps = 0
        if initial is not None:
            self.publish(initial)

    def publish(self, ver: IndexVersion) -> IndexVersion:
        with self._lock:
            if self._current is not None and \
                    ver.epoch < self._current.epoch:
                raise StaleEpochError(
                    f"publish of version {ver.version} carries epoch "
                    f"{ver.epoch} but the registry is at epoch "
                    f"{self._current.epoch} — a background rebuild "
                    f"published first; re-read current() and redo the "
                    f"mutation against the new index")
            if self._current is not None and \
                    ver.version <= self._current.version:
                ver = IndexVersion(self._current.version + 1, ver.index,
                                   ver.delta, ver.dead, ver.next_id,
                                   ver.seq, ver.merges, ver.epoch)
            self._current = ver
            self.swaps += 1
            return ver

    def current(self) -> IndexVersion:
        with self._lock:
            if self._current is None:
                raise RuntimeError("registry holds no published version")
            return self._current

    # -- persistence ---------------------------------------------------------
    def save(self, manager) -> str:
        """Write the current version through a CheckpointManager."""
        ver = self.current()
        ix = ver.index
        tree = {
            "centroids": ix.centroids, "docs": ix.docs,
            "doc_ids": ix.doc_ids, "offsets": ix.cluster_offsets,
            "sizes": ix.cluster_sizes,
            "dvecs": ver.delta.vecs, "dids": ver.delta.ids,
            "dassign": ver.delta.assign, "dead": ver.dead,
            "meta": np.asarray(
                [ix.list_pad, ver.version, ver.next_id, ver.seq,
                 ver.merges, ver.epoch], np.int64),
        }
        return manager.save(ver.version, tree)

    @staticmethod
    def restore(manager, step: Optional[int] = None
                ) -> Tuple["IndexRegistry", IndexVersion]:
        step, arrs = manager.load_arrays(step)
        missing = [k for k in _SNAPSHOT_KEYS if k not in arrs]
        if missing:
            raise CheckpointError(
                f"index snapshot at step {step} under {manager.root!r} "
                f"is missing arrays {missing} — expected the schema "
                f"written by IndexRegistry.save: {list(_SNAPSHOT_KEYS)} "
                f"(was this checkpoint written by a different tree?)")
        meta = np.asarray(arrs["meta"]).ravel()
        if meta.size < 3:
            raise CheckpointError(
                f"index snapshot at step {step} under {manager.root!r} "
                f"has a malformed 'meta' array of size {meta.size} — "
                f"expected >= 3 entries [list_pad, version, next_id"
                f"(, seq, merges)]")
        list_pad, version, next_id = (int(x) for x in meta[:3])
        seq = int(meta[3]) if meta.size > 3 else version
        merges = int(meta[4]) if meta.size > 4 else 0
        epoch = int(meta[5]) if meta.size > 5 else 0
        ver = IndexVersion(
            version=version,
            index=IVFIndex(jnp.asarray(arrs["centroids"]),
                           jnp.asarray(arrs["docs"]),
                           jnp.asarray(arrs["doc_ids"]),
                           jnp.asarray(arrs["offsets"]),
                           jnp.asarray(arrs["sizes"]), list_pad),
            delta=DeltaView(jnp.asarray(arrs["dvecs"]),
                            jnp.asarray(arrs["dids"]),
                            jnp.asarray(arrs["dassign"])),
            dead=jnp.asarray(arrs["dead"]),
            next_id=next_id,
            seq=seq,
            merges=merges,
            epoch=epoch)
        return IndexRegistry(ver), ver

    @staticmethod
    def recover(manager, wal=None, *, step: Optional[int] = None,
                align: int = 64, round_total_to: int = 4096):
        """Crash recovery: latest snapshot + WAL replay past it.

        Returns ``(registry, live, replay_report)`` where ``live`` is a
        :class:`repro.index.live.LiveIndex` bit-identical (top-k ids,
        φ history, probe counts) to the instance that crashed, and the
        registry holds its freshly published current version.
        ``replay_report`` is None when no WAL is given.

        If the WAL shows a background rebuild in flight at crash time,
        the two-phase protocol is resolved first: a durable
        ``REBUILD_COMMIT`` whose staged snapshot was not yet promoted
        gets its promote redone (the commit record *is* the publish);
        an open epoch (``BEGIN`` without ``COMMIT``/``ABORT``) is
        aborted and its staging cleaned, so recovery lands on the
        pre-rebuild snapshot + full replay — bit-identical either way.
        """
        from repro.index.live import LiveIndex
        from repro.index.rebuild import resolve_pending_rebuild
        promoted = aborted = False
        if wal is not None:
            promoted, aborted = resolve_pending_rebuild(manager, wal)
        _, ver = IndexRegistry.restore(manager, step)
        live = LiveIndex.from_version(ver, align=align,
                                      round_total_to=round_total_to,
                                      wal=wal)
        if wal is not None:
            wal.note_durable(live.seq)   # restored snapshot is durable
        report = wal.replay_into(live) if wal is not None else None
        if report is not None:
            report.rebuild_promoted = promoted
            report.rebuild_aborted = aborted
        reg = IndexRegistry(version_of(live))
        return reg, live, report
