"""Mutation write-ahead log: crash-safe durability for the live index.

Every ``LiveIndex`` mutation (``add``/``delete``/``merge_delta``)
appends one fsync'd record *before* the in-memory state changes, so a
process killed at any mutation boundary can be rebuilt exactly:

    snapshot (IndexRegistry.save)  +  replay of records with
    seq > snapshot.seq             ==  the uncrashed LiveIndex

Replay is bit-identical — external ids are allocated sequentially from
the restored ``next_id``, cluster assignment is deterministic, and
``merge_delta`` is a pure function of (index, delta) state — so the
recovered index serves the same top-k ids, probe counts and φ history
as the run that never crashed (tests/test_wal_recovery.py).

On-disk format (little-endian, append-only):

    file magic  ``EEWAL001`` (8 bytes)
    record      ``\\xa5Z`` | op u8 | seq u64 | payload_len u32 | crc32 u32
                | payload (``np.save`` bytes: f32 (m,d) vecs for add,
                  i64 ids for delete, empty for merge)

A crash mid-append leaves a truncated final record: replay drops the
torn tail and reports it.  A bad magic/CRC *before* the tail means real
corruption and raises :class:`WALCorruptError` with the file offset.

Epoch records (background re-clustering, ``repro.index.rebuild``): a
rebuild brackets itself in the log with ``REBUILD_BEGIN`` /
``REBUILD_COMMIT`` / ``REBUILD_ABORT`` records (payload: i64
``[epoch, seq]``).  They are *fences*, not mutations — replay skips
them — but they drive two guarantees:

* ``REBUILD_COMMIT`` is the atomic publish point of the two-phase
  rebuild: the staged candidate snapshot becomes the recovery base the
  instant the commit record is durable (``IndexRegistry.recover``
  redoes the promote if the crash hit between commit and rename).
* An *open* epoch (``BEGIN`` without ``COMMIT``/``ABORT``) pins every
  record newer than its fence sequence: ``truncate_upto`` refuses to
  compact past it, so the catch-up replay that the rebuild needs can
  never lose records to a concurrent compaction.
"""
from __future__ import annotations

import io
import os
import struct
import zlib
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

FILE_MAGIC = b"EEWAL001"
_REC_MAGIC = b"\xa5Z"
_HDR = struct.Struct("<2sBQII")          # magic, op, seq, len, crc

OP_ADD, OP_DELETE, OP_MERGE = 1, 2, 3
OP_REBUILD_BEGIN, OP_REBUILD_COMMIT, OP_REBUILD_ABORT = 4, 5, 6
_OP_NAMES = {OP_ADD: "add", OP_DELETE: "delete", OP_MERGE: "merge",
             OP_REBUILD_BEGIN: "rebuild_begin",
             OP_REBUILD_COMMIT: "rebuild_commit",
             OP_REBUILD_ABORT: "rebuild_abort"}
#: ops that mutate index state (replayed); the rest are epoch fences
MUTATION_OPS = (OP_ADD, OP_DELETE, OP_MERGE)
EPOCH_OPS = (OP_REBUILD_BEGIN, OP_REBUILD_COMMIT, OP_REBUILD_ABORT)


class WALCorruptError(RuntimeError):
    """The log is damaged beyond the tolerated torn tail."""


@dataclass(frozen=True)
class WALRecord:
    seq: int
    op: int
    payload: Optional[np.ndarray]        # None for merge

    @property
    def op_name(self) -> str:
        return _OP_NAMES[self.op]

    @property
    def epoch(self) -> Optional[int]:
        """Epoch number carried by a rebuild fence record (else None)."""
        if self.op in EPOCH_OPS and self.payload is not None \
                and self.payload.size:
            return int(np.asarray(self.payload).ravel()[0])
        return None


@dataclass
class ReplayReport:
    applied: int = 0
    skipped: int = 0
    torn_tail: bool = False
    last_seq: int = 0
    epoch_records: int = 0               # rebuild fences seen (not applied)
    rebuild_promoted: bool = False       # recover redid a commit's promote
    rebuild_aborted: bool = False        # recover aborted an open rebuild


def _encode_payload(arr: Optional[np.ndarray]) -> bytes:
    if arr is None:
        return b""
    buf = io.BytesIO()
    np.save(buf, np.ascontiguousarray(arr), allow_pickle=False)
    return buf.getvalue()


def _decode_payload(raw: bytes) -> Optional[np.ndarray]:
    if not raw:
        return None
    return np.load(io.BytesIO(raw), allow_pickle=False)


class MutationWAL:
    """Append-only fsync'd mutation log (one writer, many readers).

    Group commit (high mutation rates): ``group_commit_n > 1`` defers
    the fsync until that many records are pending, ``group_commit_ms``
    until that much wall time has passed since the first pending
    record (checked on the next append/flush — the API is synchronous,
    there is no background flusher).  Records are still *written* (and
    OS-visible to ``scan``) immediately; only durability is batched.
    ``flush()`` forces the fsync, and is called automatically on
    ``close`` and before ``truncate_upto`` — callers force it at
    merge/snapshot boundaries so a snapshot never outruns its log.
    Crash semantics are unchanged: the tail of the file is at worst a
    batch of whole records plus one torn record, and replay already
    tolerates a torn tail; durable-loss is bounded by the group window
    instead of zero.  Defaults (``group_commit_n=1``) keep the classic
    fsync-per-append behavior.
    """

    def __init__(self, path: str, *, fsync: bool = True,
                 group_commit_n: int = 1, group_commit_ms: float = 0.0,
                 clock=None):
        import time as _time
        self.path = path
        self.fsync = fsync
        self.group_commit_n = max(1, int(group_commit_n))
        self.group_commit_ms = float(group_commit_ms)
        self._now = clock or _time.monotonic
        self._pending = 0            # records written but not fsync'd
        self._group_t0: Optional[float] = None
        self.fsyncs = 0              # accounting (tests/benchmarks)
        self.last_scan_torn = False
        self._durable_seq: Optional[int] = None   # see note_durable()
        size = os.path.getsize(path) if os.path.exists(path) else -1
        if 0 < size < len(FILE_MAGIC):
            # crash during creation: no record can fit, safe to reset
            os.truncate(path, 0)
            size = 0
        self._f = open(path, "ab")
        if size <= 0:
            self._f.write(FILE_MAGIC)
            self._sync()
        else:
            with open(path, "rb") as f:
                if f.read(len(FILE_MAGIC)) != FILE_MAGIC:
                    raise WALCorruptError(
                        f"{path}: bad file magic — not a mutation WAL "
                        f"(expected {FILE_MAGIC!r}); refusing to append")

    # -- write ---------------------------------------------------------------
    def _sync(self) -> None:
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
            self.fsyncs += 1
        self._pending = 0
        self._group_t0 = None

    def append(self, op: int, seq: int,
               payload: Optional[np.ndarray] = None, *,
               force: bool = False) -> None:
        """Append one record.  ``force`` fsyncs regardless of the
        group-commit window (merge/snapshot boundaries)."""
        if op not in _OP_NAMES:
            raise ValueError(f"unknown WAL op {op}")
        raw = _encode_payload(payload)
        hdr = _HDR.pack(_REC_MAGIC, op, seq, len(raw), zlib.crc32(raw))
        self._f.write(hdr + raw)         # single write: tail is one record
        self._pending += 1
        if self._group_t0 is None:
            self._group_t0 = self._now()
        due = (force or self._pending >= self.group_commit_n
               or (self.group_commit_ms > 0
                   and (self._now() - self._group_t0) * 1000.0
                   >= self.group_commit_ms))
        if due:
            self._sync()
        else:
            self._f.flush()              # OS-visible, not yet durable

    def flush(self) -> None:
        """Force any pending group-commit batch to disk."""
        if self._pending:
            self._sync()
        else:
            self._f.flush()

    def note_durable(self, seq: int) -> None:
        """Record that a snapshot covering every record with sequence
        ``<= seq`` is durable on disk.  ``truncate_upto`` clamps its
        cut to this fence, so a caller passing a too-new sequence (a
        compaction racing a snapshot, or running mid-recovery) can
        never drop records that replay still needs.  Callers invoke it
        after ``IndexRegistry.save`` lands; ``recover`` sets it from
        the snapshot it restored."""
        if self._durable_seq is None or seq > self._durable_seq:
            self._durable_seq = int(seq)

    def close(self) -> None:
        if not self._f.closed:
            self.flush()                 # never drop a pending batch
            self._f.close()

    def __enter__(self) -> "MutationWAL":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read ----------------------------------------------------------------
    def scan(self) -> List[WALRecord]:
        """All complete records, oldest first.

        Tolerates a truncated final record (crash mid-append) — sets
        ``last_scan_torn`` — but raises :class:`WALCorruptError` on a
        damaged record that is *followed* by more data.
        """
        self._f.flush()
        out: List[WALRecord] = []
        self.last_scan_torn = False
        size = os.path.getsize(self.path)
        with open(self.path, "rb") as f:
            if f.read(len(FILE_MAGIC)) != FILE_MAGIC:
                raise WALCorruptError(
                    f"{self.path}: bad file magic — expected "
                    f"{FILE_MAGIC!r} (file written by MutationWAL)")
            while True:
                off = f.tell()
                hdr = f.read(_HDR.size)
                if not hdr:
                    break
                if len(hdr) < _HDR.size:
                    self.last_scan_torn = True
                    break
                magic, op, seq, plen, crc = _HDR.unpack(hdr)
                if magic != _REC_MAGIC or op not in _OP_NAMES:
                    raise WALCorruptError(
                        f"{self.path}: bad record header at byte {off} "
                        f"(magic={magic!r} op={op}); the log is corrupt "
                        f"before its tail — restore from an older "
                        f"snapshot or truncate the file at that offset")
                raw = f.read(plen)
                if len(raw) < plen:
                    self.last_scan_torn = True
                    break
                if zlib.crc32(raw) != crc:
                    if off + _HDR.size + plen >= size:
                        self.last_scan_torn = True   # torn tail payload
                        break
                    raise WALCorruptError(
                        f"{self.path}: CRC mismatch in record at byte "
                        f"{off} (seq={seq}, op={_OP_NAMES[op]}) with "
                        f"valid data after it — the log is corrupt")
                out.append(WALRecord(seq, op, _decode_payload(raw)))
        return out

    # -- replay --------------------------------------------------------------
    def replay_into(self, live) -> ReplayReport:
        """Re-apply every record newer than ``live.seq`` (the snapshot
        sequence number) onto a restored LiveIndex, in order."""
        rep = ReplayReport()
        records = self.scan()
        rep.torn_tail = self.last_scan_torn
        live._replaying = True
        try:
            for rec in records:
                if rec.op not in MUTATION_OPS:
                    rep.epoch_records += 1    # rebuild fence, not a mutation
                    continue
                if rec.seq <= live.seq:
                    rep.skipped += 1
                    continue
                if rec.seq != live.seq + 1:
                    raise WALCorruptError(
                        f"{self.path}: sequence gap — record seq="
                        f"{rec.seq} but index is at seq={live.seq}; a "
                        f"record is missing (log truncated mid-stream?)")
                if rec.op == OP_ADD:
                    live.add(rec.payload)
                elif rec.op == OP_DELETE:
                    live.delete(rec.payload)
                else:
                    live.merge_delta()
                rep.applied += 1
        finally:
            live._replaying = False
        rep.last_seq = live.seq
        return rep

    # -- maintenance ---------------------------------------------------------
    def open_epoch_fences(self, records=None) -> List[int]:
        """Fence sequences of rebuilds that are in flight (a
        ``REBUILD_BEGIN`` with no matching ``COMMIT``/``ABORT``)."""
        begun, closed = {}, set()
        for r in (self.scan() if records is None else records):
            if r.op == OP_REBUILD_BEGIN:
                pl = np.asarray(r.payload).ravel()
                begun[int(pl[0])] = int(pl[1]) if pl.size > 1 else r.seq
            elif r.op in (OP_REBUILD_COMMIT, OP_REBUILD_ABORT):
                closed.add(r.epoch)
        return [f for e, f in begun.items() if e not in closed]

    def truncate_upto(self, seq: int) -> int:
        """Drop records with ``seq <=`` the given snapshot sequence
        (log compaction after a successful snapshot).  Returns the
        number of records kept.  Atomic: rewrite + rename.

        Guarded: the cut is clamped to (a) the last sequence reported
        durable via :meth:`note_durable` and (b) the fence of any open
        rebuild epoch, so compaction can never drop a record that
        snapshot recovery or an in-flight rebuild's catch-up replay
        still needs — even when the caller passes a sequence from the
        future (e.g. a compaction interleaved with recovery).  Fence
        records of open epochs are always kept; fences of resolved
        epochs compact away with the mutations they bracket."""
        self.flush()                     # batch must land before rewrite
        records = self.scan()
        cut = int(seq)
        if self._durable_seq is not None:
            cut = min(cut, self._durable_seq)
        fences = self.open_epoch_fences(records)
        if fences:
            cut = min(cut, min(fences))
        open_epochs = set()
        begun, closed = set(), set()
        for r in records:
            if r.op == OP_REBUILD_BEGIN:
                begun.add(r.epoch)
            elif r.op in (OP_REBUILD_COMMIT, OP_REBUILD_ABORT):
                closed.add(r.epoch)
        open_epochs = begun - closed
        keep = [r for r in records
                if r.seq > cut
                or (r.op in EPOCH_OPS and r.epoch in open_epochs)]
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(FILE_MAGIC)
            for r in keep:
                raw = _encode_payload(r.payload)
                f.write(_HDR.pack(_REC_MAGIC, r.op, r.seq, len(raw),
                                  zlib.crc32(raw)) + raw)
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")
        return len(keep)
