"""Background re-clustering: crash-safe two-phase rebuild of the index.

``merge_delta`` keeps centroids fixed, so sustained churn drifts the
corpus away from its cluster structure and erodes the recall that
patience-based early exit depends on.  The :class:`Rebuilder` fixes
that *online*: it re-trains centroids off the serving path and swaps
the re-clustered index in without pausing reads or losing a single
mutation.

Pipeline (one stage per ``tick()``, so the serving loop can interleave
waves and throttle under deadline pressure):

    begin    fence the WAL (``REBUILD_BEGIN [epoch, fence_seq]``) and
             snapshot the net corpus (base + delta − tombstones) plus
             fence-time next_id / tombstone set.
    retrain  warm-start Lloyd (``core.kmeans.retrain``) from the
             serving centroids — cluster count stays fixed.
    layout   assign the snapshot to the new centroids and re-layout a
             candidate index; entries overflowing ``list_pad`` spill
             into the candidate's delta buffer (merge_delta rule:
             first-come keeps the slot).
    catchup  replay WAL records with ``seq > fence_seq`` onto the
             candidate — mutations that arrived *during* the rebuild.
             Deterministic: adds re-assign to the NEW centroids, ids
             allocate sequentially from the fence-time next_id.
    publish  two-phase commit, then an epoch-bumped registry publish.

Two-phase publish (the crash-safety headline):

    1. save the candidate snapshot into ``<root>/rebuild_staging/``
       (its own CheckpointManager — the main manager never sees
       uncommitted state);
    2. append ``REBUILD_COMMIT [epoch, step]`` to the WAL, fsync'd —
       THE atomic commit point;
    3. promote: ``os.replace`` the staged step dir into the main
       snapshot root;
    4. compact the WAL past the candidate's sequence and publish the
       epoch-bumped version through the registry.

``resolve_pending_rebuild`` (called by ``IndexRegistry.recover``
before restoring) makes every crash window land bit-identically:

    crash before step 2  ->  the epoch is open: append
        ``REBUILD_ABORT``, clean staging, recover = pre-rebuild
        snapshot + full replay (exactly the no-rebuild state).
    crash between 2 and 3  ->  the commit record is durable but the
        staged dir was never promoted: redo the promote, recover from
        the candidate (exactly the post-rebuild state).
    crash after 3  ->  the candidate is already the latest snapshot;
        nothing to resolve.

Epoch fencing: the published version carries ``epoch = old + 1``.
``IndexRegistry.publish`` raises :class:`~repro.index.registry.
StaleEpochError` for any version with a lower epoch, so a
``merge_delta`` computed against pre-rebuild centroids can never
clobber the re-clustered index (its mutations are safe — they are in
the WAL and were caught up onto the candidate).  Readers
(``WaveScheduler``) drain in-flight lanes before adopting a
higher-epoch version, because probe order is only valid within one
centroid generation.
"""
from __future__ import annotations

import os
import shutil
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import numpy as np

from repro.core import kmeans
from repro.index.delta import DeltaBuffer
from repro.index.live import LiveIndex, relayout
from repro.index.registry import IndexRegistry, IndexVersion, version_of
from repro.index.wal import (MUTATION_OPS, OP_REBUILD_ABORT,
                             OP_REBUILD_BEGIN, OP_REBUILD_COMMIT)

#: staged (uncommitted) candidate snapshots live here, under the main
#: CheckpointManager root — ``all_steps`` never lists them, so an
#: uncommitted candidate can never be restored by accident.
STAGING_DIR = "rebuild_staging"

#: ordered pipeline stages (``Rebuilder.stage`` walks this list)
STAGES = ("begin", "retrain", "layout", "catchup", "publish")

#: failpoint names accepted by ``Rebuilder(failpoint=...)`` — each
#: simulates a crash at one boundary of the protocol (chaos drills)
FAILPOINTS = ("begin", "retrain", "catchup", "staged", "commit",
              "promote")


class RebuildCrash(RuntimeError):
    """Simulated crash at a rebuild failpoint (chaos drills only).

    Deliberately NOT handled by the Rebuilder: state is left exactly
    as a real crash would leave it, so the drill can exercise
    ``IndexRegistry.recover`` against it.
    """


@dataclass
class RebuildReport:
    epoch: int = 0
    fence_seq: int = 0
    corpus: int = 0              # net docs snapshotted at the fence
    spilled: int = 0             # overflow entries -> candidate delta
    caught_up: int = 0           # WAL records replayed onto candidate
    moved: int = 0               # docs whose cluster changed
    step: int = -1               # promoted snapshot step (-1: no mgr)
    published_version: int = -1
    reason: str = "manual"


class DriftTracker:
    """Centroid-drift trigger: mean nearest-centroid squared distance
    of recently *added* vectors, as a ratio over the same statistic of
    the corpus at (re)build time.  A ratio persistently above
    ``threshold`` means new documents land far from every centroid —
    cluster structure has drifted and a rebuild will restore recall.
    ``observe`` smooths with an EMA so one odd batch does not trigger.
    """

    def __init__(self, centroids, baseline_vecs=None, *,
                 ema: float = 0.9, threshold: float = 1.5):
        if not 0.0 <= ema < 1.0:
            raise ValueError(f"ema must be in [0, 1), got {ema}")
        self._ema = float(ema)
        self.threshold = float(threshold)
        self.rebase(centroids, baseline_vecs)

    @staticmethod
    def _mean_dist(vecs, centroids) -> float:
        """Mean over rows of min_c |x - c|^2 (exact, host-side)."""
        v = np.asarray(vecs, np.float32)
        if v.size == 0:
            return 0.0
        c = np.asarray(centroids, np.float32)
        sims = v @ c.T - 0.5 * (c * c).sum(1)[None, :]
        return float(np.mean((v * v).sum(1) - 2.0 * sims.max(1)))

    def rebase(self, centroids, baseline_vecs=None) -> None:
        """Reset after a rebuild: new centroids, fresh baseline."""
        self.centroids = np.asarray(centroids, np.float32)
        self.baseline: Optional[float] = None
        if baseline_vecs is not None:
            self.baseline = max(self._mean_dist(baseline_vecs,
                                                self.centroids), 1e-12)
        self.current: Optional[float] = None

    def observe(self, vecs) -> float:
        """Fold one batch of added vectors in; returns the ratio.
        The first batch seeds the baseline when none was given."""
        d = self._mean_dist(vecs, self.centroids)
        if self.baseline is None:
            self.baseline = max(d, 1e-12)
        self.current = d if self.current is None else \
            self._ema * self.current + (1.0 - self._ema) * d
        return self.ratio

    @property
    def ratio(self) -> float:
        if self.current is None or self.baseline is None:
            return 0.0
        return self.current / self.baseline

    @property
    def triggered(self) -> bool:
        return self.ratio > self.threshold


def resolve_pending_rebuild(manager, wal) -> Tuple[bool, bool]:
    """Resolve an interrupted two-phase rebuild before restore.

    Returns ``(promoted, aborted)``: whether a durable COMMIT's
    promote was redone, and whether an open epoch was aborted.
    Idempotent — running it twice (or on a clean log) is a no-op.
    """
    records = wal.scan()
    begun, committed, closed = {}, {}, set()
    last_seq = 0
    for r in records:
        if r.op in MUTATION_OPS:
            last_seq = max(last_seq, r.seq)
            continue
        pl = np.asarray(r.payload).ravel()
        e = int(pl[0])
        if r.op == OP_REBUILD_BEGIN:
            begun[e] = r
        elif r.op == OP_REBUILD_COMMIT:
            committed[e] = int(pl[1]) if pl.size > 1 else -1
            closed.add(e)
        elif r.op == OP_REBUILD_ABORT:
            closed.add(e)
    promoted = aborted = False
    staging = os.path.join(manager.root, STAGING_DIR)
    # 1. redo the promote for any committed candidate still staged
    #    (crash hit between the COMMIT record and the rename)
    for e, step in committed.items():
        if step < 0:
            continue
        src = os.path.join(staging, f"step_{step:08d}")
        dst = os.path.join(manager.root, f"step_{step:08d}")
        if os.path.isdir(src):
            if os.path.isdir(dst):       # promoted AND staged: stale copy
                shutil.rmtree(src, ignore_errors=True)
            else:
                os.replace(src, dst)
                promoted = True
    # 2. abort any epoch still open (crash before its COMMIT): the
    #    staged candidate — if it even exists — was never committed,
    #    so recovery must land on the pre-rebuild snapshot + replay
    for e in begun:
        if e in closed:
            continue
        wal.append(OP_REBUILD_ABORT, last_seq,
                   np.asarray([e, 0], np.int64), force=True)
        aborted = True
    # any staging left over belongs to a closed epoch now — drop it
    if os.path.isdir(staging):
        shutil.rmtree(staging, ignore_errors=True)
    return promoted, aborted


class Rebuilder:
    """Online background re-clustering with two-phase crash-safe publish.

    One ``tick()`` runs one pipeline stage (begin → retrain → layout →
    catchup → publish), so a serving loop can interleave waves between
    stages and skip ticks entirely under deadline pressure
    (``DegradationLadder.throttle_rebuild``).  ``run_once()`` drives a
    whole rebuild synchronously.

    ``manager`` (CheckpointManager) and ``wal`` are optional: without
    them the rebuild is in-memory only (useful as a test oracle), but
    then no mutations may arrive between ``begin`` and ``publish``.
    ``failpoint`` names a protocol boundary at which to raise
    :class:`RebuildCrash` (see :data:`FAILPOINTS`), leaving disk state
    exactly as a real crash would — chaos drills recover from it.
    ``on_publish(new_live, report)`` fires after the registry swap so
    the mutation driver can rebind its LiveIndex handle.
    """

    def __init__(self, live: LiveIndex, registry: Optional[IndexRegistry]
                 = None, manager=None, *, n_iters: int = 4,
                 block: int = 4096,
                 on_publish: Optional[Callable] = None,
                 failpoint: Optional[str] = None):
        if failpoint is not None and failpoint not in FAILPOINTS:
            raise ValueError(
                f"unknown failpoint {failpoint!r}; expected one of "
                f"{FAILPOINTS}")
        self.live = live
        self.registry = registry
        self.manager = manager
        self.n_iters = int(n_iters)
        self.block = int(block)
        self.on_publish = on_publish
        self.failpoint = failpoint
        self.stage: str = "idle"
        self.epochs_published = 0
        self.last_report: Optional[RebuildReport] = None
        self._reset()

    # -- control -------------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.stage != "idle"

    def request(self, reason: str = "manual") -> bool:
        """Arm a rebuild; returns False if one is already in flight."""
        if self.active:
            return False
        self._reset()
        self._reason = reason
        self.stage = STAGES[0]
        return True

    def tick(self) -> Optional[str]:
        """Run ONE pipeline stage; returns its name (None when idle).
        A real error aborts the rebuild (epoch closed, staging
        cleaned) and re-raises; a :class:`RebuildCrash` failpoint
        propagates raw, leaving crash-consistent state behind."""
        if not self.active:
            return None
        stage = self.stage
        try:
            getattr(self, "_stage_" + stage)()
        except RebuildCrash:
            raise
        except Exception:
            self.abort()
            raise
        return stage

    def run_once(self, reason: str = "manual"
                 ) -> Optional[RebuildReport]:
        """Drive a full rebuild synchronously; returns its report."""
        if not self.request(reason) and not self.active:
            return None
        while self.active:
            self.tick()
        return self.last_report

    def abort(self) -> None:
        """Close the epoch (``REBUILD_ABORT``) and drop staged state.
        Safe to call at any point before publish; idempotent."""
        if self._begun and self.live.wal is not None:
            self.live.wal.append(
                OP_REBUILD_ABORT, self.live.seq,
                np.asarray([self._epoch, 0], np.int64), force=True)
        if self.manager is not None:
            shutil.rmtree(os.path.join(self.manager.root, STAGING_DIR),
                          ignore_errors=True)
        self._reset()

    def _reset(self) -> None:
        self.stage = "idle"
        self._reason = "manual"
        self._begun = False
        self._epoch = 0
        self._fence_seq = 0
        self._snap_vecs = self._snap_ids = None
        self._fence_next_id = 0
        self._fence_dead = None
        self._new_centroids = None
        self._assign = None
        self._candidate: Optional[LiveIndex] = None
        self._spilled = 0
        self._caught_up = 0
        self._step = -1

    def _maybe_crash(self, point: str) -> None:
        if self.failpoint == point:
            raise RebuildCrash(f"simulated crash at rebuild "
                               f"failpoint {point!r}")

    # -- stages --------------------------------------------------------------
    def _stage_begin(self) -> None:
        live = self.live
        self._epoch = live.epoch + 1
        self._fence_seq = live.seq
        if live.wal is not None:
            live.wal.append(
                OP_REBUILD_BEGIN, self._fence_seq,
                np.asarray([self._epoch, self._fence_seq], np.int64),
                force=True)
        self._begun = True
        self._maybe_crash("begin")
        self._snap_vecs, self._snap_ids = live.net_corpus()
        self._fence_next_id = live.next_id
        self._fence_dead = np.asarray(live.dead_lookup()).copy()
        self.stage = "retrain"

    def _stage_retrain(self) -> None:
        self._maybe_crash("retrain")
        self._new_centroids, self._assign = kmeans.retrain(
            self._snap_vecs, self.live._centroids,
            n_iters=self.n_iters, block=self.block)
        self.stage = "layout"

    def _stage_layout(self) -> None:
        live = self.live
        lp = live.index.list_pad
        vecs, ids = self._snap_vecs, self._snap_ids
        assign = np.asarray(self._assign, np.int32)
        n = vecs.shape[0]
        c = self._new_centroids.shape[0]
        # merge_delta spill rule under the new assignment: within a
        # cluster, earlier corpus entries keep their list slot; the
        # overflow past list_pad spills to the candidate's buffer
        fill = np.zeros(c, np.int64)
        keep = np.ones(n, bool)
        for i, cl in enumerate(assign):
            if fill[cl] >= lp:
                keep[i] = False
            else:
                fill[cl] += 1
        spill = np.nonzero(~keep)[0]
        if spill.size > live.delta.capacity:
            raise RuntimeError(
                f"rebuild would spill {spill.size} overflow entries "
                f"but the delta buffer holds {live.delta.capacity}; "
                f"raise list_pad or delta capacity")
        cand_index = relayout(vecs[keep], ids[keep], assign[keep],
                              self._new_centroids, list_pad=lp,
                              align=live.align,
                              round_total_to=live.round_total_to)
        buf = DeltaBuffer(live.index.dim, live.delta.capacity)
        if spill.size:
            buf.add(vecs[spill], ids[spill], assign[spill])
        ver = IndexVersion(
            version=self._fence_seq, index=cand_index, delta=buf.view(),
            dead=self._fence_dead, next_id=self._fence_next_id,
            seq=self._fence_seq, merges=live.version, epoch=self._epoch)
        self._candidate = LiveIndex.from_version(
            ver, align=live.align, round_total_to=live.round_total_to)
        self._spilled = int(spill.size)
        self.stage = "catchup"

    def _stage_catchup(self) -> None:
        self._maybe_crash("catchup")
        self._caught_up = self._do_catchup()
        self.stage = "publish"

    def _do_catchup(self) -> int:
        """Replay WAL records past the candidate's sequence onto it
        (mutations that landed while the rebuild ran).  Adds re-assign
        to the NEW centroids; id allocation continues from the
        fence-time next_id — both deterministic, so recovery replays
        to the bit-identical candidate."""
        cand, live = self._candidate, self.live
        if cand.seq >= live.seq:
            return 0
        if live.wal is None:
            raise RuntimeError(
                f"{live.seq - cand.seq} mutations arrived during an "
                f"in-memory rebuild (no WAL to catch up from); attach "
                f"a MutationWAL or quiesce writes across run_once()")
        live.wal.flush()
        rep = live.wal.replay_into(cand)
        return rep.applied

    def _stage_publish(self) -> None:
        live, cand = self.live, self._candidate
        self._caught_up += self._do_catchup()    # close any late gap
        wal = live.wal
        if self.manager is not None and wal is not None:
            # two-phase commit: stage -> COMMIT record -> promote
            from repro.checkpoint.manager import CheckpointManager
            self.manager.wait()
            self._step = max(self.manager.latest_step() or -1,
                             self.registry.current().version
                             if self.registry is not None else -1,
                             cand.seq) + 1
            staging = CheckpointManager(
                os.path.join(self.manager.root, STAGING_DIR),
                keep=self.manager.keep, async_save=False)
            IndexRegistry(version_of(cand, version=self._step)
                          ).save(staging)
            self._maybe_crash("staged")
            wal.append(OP_REBUILD_COMMIT, cand.seq,
                       np.asarray([self._epoch, self._step], np.int64),
                       force=True)              # THE atomic commit point
            self._maybe_crash("commit")
            os.replace(
                os.path.join(staging.root, f"step_{self._step:08d}"),
                os.path.join(self.manager.root, f"step_{self._step:08d}"))
            shutil.rmtree(staging.root, ignore_errors=True)
            self._maybe_crash("promote")
            wal.note_durable(cand.seq)
            wal.truncate_upto(cand.seq)
        elif wal is not None:
            # no snapshot manager: the rebuild cannot be made durable,
            # so close the epoch on the log — a crash after this
            # publish recovers to pre-rebuild centroids + full replay
            # (consistent, no lost mutations; just not re-clustered)
            wal.append(OP_REBUILD_ABORT, cand.seq,
                       np.asarray([self._epoch, 0], np.int64),
                       force=True)
        cand.wal = wal
        report = RebuildReport(
            epoch=self._epoch, fence_seq=self._fence_seq,
            corpus=int(self._snap_vecs.shape[0]), spilled=self._spilled,
            caught_up=self._caught_up,
            moved=self._count_moved(), step=self._step,
            reason=self._reason)
        pub = None
        if self.registry is not None:
            pub = self.registry.publish(version_of(cand))
            report.published_version = pub.version
        self.live = cand
        self.epochs_published += 1
        self.last_report = report
        self.stage = "idle"
        if self.on_publish is not None:
            self.on_publish(cand, report)

    def _count_moved(self) -> int:
        """Docs whose cluster changed under the new centroids (the
        snapshot portion only — a cheap drift-repair indicator).
        ``self.live`` still points at the pre-publish index here."""
        from repro.index.delta import assign_clusters
        if self._snap_vecs is None or not self._snap_vecs.size:
            return 0
        prev = assign_clusters(self._snap_vecs, self.live._centroids)
        return int((np.asarray(self._assign) != prev).sum())
