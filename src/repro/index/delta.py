"""Delta buffer + tombstone set: the mutable half of the live index.

Both structures are host-coordinated (mutations arrive over the
serving control plane, not inside jit) but expose fixed-shape device
views so the hot search/serve paths never re-trace as documents come
and go:

* :class:`DeltaBuffer` — a fixed-capacity, append-only staging area
  for recently added vectors.  Every entry records the cluster the
  vector will be merged into (nearest centroid, the same assignment
  rule ``merge_delta`` uses), which is what lets the overlay search
  stay bit-identical to a rebuilt index.  Slots are never reordered:
  within a cluster, merge order == insertion order == the order a
  rebuilt list would hold.
* :class:`Tombstones` — the cumulative set of deleted external doc
  ids, plus a dense device lookup used to scrub running top-k state
  that predates a deletion (mid-flight queries across version swaps).
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.ivf import DeltaView


class DeltaFull(RuntimeError):
    """The delta buffer is out of slots — run ``merge_delta()``."""


def assign_clusters(vecs: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Nearest-centroid assignment, same rule as the k-means builder
    (``kmeans._assign_block``): argmax of x.c - 0.5|c|^2 (squared-L2
    nearest centroid with the |x|^2 term dropped)."""
    centroids = np.asarray(centroids, np.float32)
    sims = np.asarray(vecs, np.float32) @ centroids.T \
        - 0.5 * (centroids * centroids).sum(1)[None, :]
    return np.argmax(sims, axis=1).astype(np.int32)


class DeltaBuffer:
    def __init__(self, dim: int, capacity: int = 1024, *,
                 round_to: int = 128):
        cap = max(round_to, -(-capacity // round_to) * round_to)
        self.capacity = cap
        self.vecs = np.zeros((cap, dim), np.float32)
        self.ids = np.full(cap, -1, np.int32)
        self.assign = np.full(cap, -1, np.int32)
        self.count = 0                      # slots consumed (append ptr)
        self._slot_of = {}                  # external id -> slot
        self._view: Optional[DeltaView] = None

    def __len__(self) -> int:
        return int((self.ids >= 0).sum())

    def occupancy(self) -> float:
        return self.count / self.capacity

    def ensure_room(self, m: int) -> None:
        if self.count + m > self.capacity:
            raise DeltaFull(
                f"delta buffer full ({self.count}/{self.capacity} slots "
                f"used, {m} more requested): call merge_delta() first")

    def add(self, vecs: np.ndarray, ids: np.ndarray,
            assign: np.ndarray) -> None:
        m = vecs.shape[0]
        self.ensure_room(m)
        sl = slice(self.count, self.count + m)
        self.vecs[sl] = vecs
        self.ids[sl] = ids
        self.assign[sl] = assign
        for j, i in enumerate(ids):
            self._slot_of[int(i)] = self.count + j
        self.count += m
        self._view = None

    def delete(self, doc_id: int) -> bool:
        """Tombstone a buffered entry in place (slot stays consumed so
        insertion order of the survivors is preserved)."""
        slot = self._slot_of.pop(int(doc_id), None)
        if slot is None:
            return False
        self.ids[slot] = -1
        self._view = None
        return True

    def live_slots(self) -> np.ndarray:
        """Slots holding a live entry, in insertion order."""
        return np.nonzero(self.ids[: self.count] >= 0)[0]

    def compact_keep(self, slots: np.ndarray) -> None:
        """Drop everything except ``slots`` (merge spill-back): the
        kept entries move to the front, preserving their order."""
        slots = np.asarray(slots, np.int64)
        m = slots.size
        self.vecs[:m] = self.vecs[slots]
        self.ids[:m] = self.ids[slots]
        self.assign[:m] = self.assign[slots]
        self.vecs[m:] = 0.0
        self.ids[m:] = -1
        self.assign[m:] = -1
        self.count = m
        self._slot_of = {int(i): s for s, i in enumerate(self.ids[:m])}
        self._view = None

    def view(self) -> DeltaView:
        """Fixed-shape device view (cached until the next mutation).

        The buffers are COPIED: on CPU ``jnp.asarray`` may alias numpy
        memory, and with async dispatch a later in-place mutation
        (``add``/``compact_keep``) could corrupt a still-executing
        search that captured this view."""
        if self._view is None:
            self._view = DeltaView(jnp.asarray(self.vecs.copy()),
                                   jnp.asarray(self.ids.copy()),
                                   jnp.asarray(self.assign.copy()))
        return self._view


class Tombstones:
    def __init__(self, id_capacity: int, *, round_to: int = 4096):
        self._cap = max(round_to, -(-id_capacity // round_to) * round_to)
        self._dead = np.zeros(self._cap, bool)
        self._round = round_to
        self.count = 0
        self._lookup: Optional[jnp.ndarray] = None

    def ensure_capacity(self, n_ids: int) -> None:
        if n_ids <= self._cap:
            return
        cap = -(-n_ids // self._round) * self._round
        grown = np.zeros(cap, bool)
        grown[: self._cap] = self._dead
        self._dead, self._cap = grown, cap
        self._lookup = None

    def add(self, ids: Iterable[int]) -> None:
        for i in ids:
            if not self._dead[int(i)]:
                self._dead[int(i)] = True
                self.count += 1
        self._lookup = None

    def __contains__(self, doc_id: int) -> bool:
        i = int(doc_id)
        return 0 <= i < self._cap and bool(self._dead[i])

    def dead_ids(self) -> np.ndarray:
        return np.nonzero(self._dead)[0].astype(np.int32)

    def lookup(self) -> jnp.ndarray:
        """(id_capacity,) bool device array for running-top-k scrubs.
        Copied for the same aliasing reason as ``DeltaBuffer.view``."""
        if self._lookup is None:
            self._lookup = jnp.asarray(self._dead.copy())
        return self._lookup
