"""Top-k merge kernel: bitonic sort network over (running-k ++ new-L).

The A-kNN inner loop merges each query's running top-k with list_pad
fresh scores every probe. The network is static (built from XOR-partner
permutations), so it lowers to lane shuffles on the VPU — no
data-dependent control flow. Scores ride with their doc ids through the
compare-exchange.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -jnp.inf


def _bitonic_desc(s: jnp.ndarray, i: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort rows of s (B, M) descending, carrying i. M = power of 2.

    The lane ^ jj partner permutation of each compare-exchange pass is
    a reshape + reverse on a length-2 axis (flip one address bit); this
    lowers to lane shuffles and keeps compile time flat in network
    depth, unlike gather-based (jnp.take) formulations.
    """
    b, m = s.shape
    idx = jnp.arange(m)
    stages = int(np.log2(m))

    def partner(x, jj):
        return jnp.flip(x.reshape(b, m // (2 * jj), 2, jj),
                        axis=2).reshape(b, m)

    for st in range(1, stages + 1):
        kk = 1 << st
        for jj in (1 << p for p in range(st - 1, -1, -1)):
            ps = partner(s, jj)
            pi = partner(i, jj)
            up = (idx & kk) == 0            # descending blocks
            is_lo = (idx & jj) == 0
            # lane keeps max if (descending and lower) or (asc and upper)
            keep_max = jnp.where(up, is_lo, ~is_lo)[None, :]
            take_p = jnp.where(keep_max, ps > s, ps < s)
            s = jnp.where(take_p, ps, s)
            i = jnp.where(take_p, pi, i)
    return s, i


def _kernel(s_ref, i_ref, ns_ref, ni_ref, os_ref, oi_ref, *, k: int,
            m_pad: int):
    s = jnp.concatenate([s_ref[...], ns_ref[...]], axis=1)
    i = jnp.concatenate([i_ref[...], ni_ref[...]], axis=1)
    pad = m_pad - s.shape[1]
    if pad:
        s = jnp.pad(s, ((0, 0), (0, pad)), constant_values=-1e30)
        i = jnp.pad(i, ((0, 0), (0, pad)), constant_values=-1)
    s = jnp.where(jnp.isfinite(s), s, -1e30)
    ss, si = _bitonic_desc(s, i)
    os_ref[...] = ss[:, :k]
    oi_ref[...] = si[:, :k]


def topk_merge(scores: jnp.ndarray, ids: jnp.ndarray,
               new_scores: jnp.ndarray, new_ids: jnp.ndarray, k: int,
               *, blk_b: int = 8, interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = scores.shape[0]
    total = scores.shape[1] + new_scores.shape[1]
    m_pad = 1 << int(np.ceil(np.log2(total)))
    blk_b = min(blk_b, b)
    if b % blk_b:
        blk_b = 1
    kern = functools.partial(_kernel, k=k, m_pad=m_pad)
    grid = (b // blk_b,)
    specs = lambda w: pl.BlockSpec((blk_b, w), lambda bi: (bi, 0))
    out_s, out_i = pl.pallas_call(
        kern, grid=grid,
        in_specs=[specs(scores.shape[1]), specs(ids.shape[1]),
                  specs(new_scores.shape[1]), specs(new_ids.shape[1])],
        out_specs=[specs(k), specs(k)],
        out_shape=[jax.ShapeDtypeStruct((b, k), scores.dtype),
                   jax.ShapeDtypeStruct((b, k), ids.dtype)],
        interpret=interpret,
    )(scores, ids, new_scores, new_ids)
    # the kernel clamps -inf to -1e30 for the sort network; map the
    # sentinel back so empty slots match the XLA merge (-inf) exactly
    out_s = jnp.where(out_s > -1e29, out_s, NEG_INF)
    return out_s, out_i
