"""Top-k merge kernel: packed bitonic network over (running-k ++ new-L).

The A-kNN inner loop merges each query's running top-k with list_pad
fresh scores every probe.  The network is the shared packed sort
(``kernels/sort.py``): scores are monotone-mapped into int32 keys and
ride stacked with their doc ids through a static XOR-partner
compare-exchange network — one shuffle + one select per pass for the
whole (score, id) record, no data-dependent control flow.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import sort

NEG_INF = -jnp.inf
_KEY_NEG = sort.key_of(-1e30)


def _kernel(s_ref, i_ref, ns_ref, ni_ref, os_ref, oi_ref, *, k: int,
            m_pad: int):
    s = jnp.concatenate([s_ref[...], ns_ref[...]], axis=1)
    i = jnp.concatenate([i_ref[...], ni_ref[...]], axis=1)
    pad = m_pad - s.shape[1]
    if pad:
        s = jnp.pad(s, ((0, 0), (0, pad)), constant_values=-1e30)
        i = jnp.pad(i, ((0, 0), (0, pad)), constant_values=-1)
    # NaN/±inf clamp BEFORE the key map: every non-finite score becomes
    # the -1e30 sentinel, so NaNs cannot leak above +inf in key space
    s = jnp.where(jnp.isfinite(s), s, -1e30)
    out = sort.bitonic_desc_packed(sort.pack(sort.score_to_key(s), i))
    os_ref[...] = sort.key_to_score(out[:, 0, :k])
    oi_ref[...] = out[:, 1, :k]


def topk_merge(scores: jnp.ndarray, ids: jnp.ndarray,
               new_scores: jnp.ndarray, new_ids: jnp.ndarray, k: int,
               *, blk_b: int = 8, interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = scores.shape[0]
    total = scores.shape[1] + new_scores.shape[1]
    m_pad = 1 << int(np.ceil(np.log2(total)))
    blk_b = min(blk_b, b)
    if b % blk_b:
        blk_b = 1
    kern = functools.partial(_kernel, k=k, m_pad=m_pad)
    grid = (b // blk_b,)
    specs = lambda w: pl.BlockSpec((blk_b, w), lambda bi: (bi, 0))
    out_s, out_i = pl.pallas_call(
        kern, grid=grid,
        in_specs=[specs(scores.shape[1]), specs(ids.shape[1]),
                  specs(new_scores.shape[1]), specs(new_ids.shape[1])],
        out_specs=[specs(k), specs(k)],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), ids.dtype)],
        interpret=interpret,
    )(scores, ids, new_scores, new_ids)
    # the kernel clamps -inf to -1e30 for the sort network; map the
    # sentinel back so empty slots match the XLA merge (-inf) exactly
    out_s = jnp.where(out_s > -1e29, out_s, NEG_INF)
    return out_s.astype(scores.dtype), out_i
