"""Packed (score, id) bitonic sort — single source of truth for every
top-k merge network in the kernels layer.

Scores are monotone-mapped into int32 *keys* (``score_to_key``): the
IEEE-754 bit pattern of a float, with the magnitude bits flipped for
negatives, compares in the same order as the float itself under signed
integer comparison.  The map is an exact involution, so scores
round-trip bit-for-bit (``key_to_score``) — including negatives,
denormals and ±inf.  NaNs map above +inf; callers that may see NaN
clamp it first (``topk_merge`` maps every non-finite score to the
``-1e30`` sentinel).

The sort then runs on a single stacked ``(R, 2, M)`` int32 array —
key word and id word — instead of separate f32 score / i32 id / i32
tag lanes: each compare-exchange pass costs ONE partner shuffle and
ONE select of the stacked array (plus one lexicographic compare),
where the tagged three-lane network paid three of each.  That halves
shuffle traffic and register pressure in every merge step of the
fused kernel.

Ties: descending lexicographic on (key, id-word), so equal scores are
broken by the *higher* id word deterministically.  The per-probe
reference (``jax.lax.top_k``) breaks exact-score ties by position
instead; bit-identity between the two therefore assumes tie-free
scores (true for the float workloads in the test batteries — exact
duplicate dot products across distinct docs).

The tag lane of the old fused kernel is replaced by one *mark bit* in
the id word (``NEW_MARK``): candidates entering a merge are marked,
survivors still marked afterwards are this probe's new entries.  Doc
ids must stay below 2**30.  The tombstone/empty id ``-1`` is never
marked and never unmarked — ``strip_marks`` masks only non-negative
words, so the sentinel survives untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_SIGN_FLIP = 0x7FFFFFFF          # flips magnitude bits of negatives
NEW_MARK = 1 << 30               # id-word bit: entered on this probe


def score_to_key(s: jnp.ndarray) -> jnp.ndarray:
    """f32 -> i32, strictly order-preserving under signed compare."""
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    return jnp.where(bits < 0, bits ^ _SIGN_FLIP, bits)


def key_to_score(key: jnp.ndarray) -> jnp.ndarray:
    """Exact inverse of :func:`score_to_key` (it is an involution)."""
    bits = jnp.where(key < 0, key ^ _SIGN_FLIP, key)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def key_of(x: float) -> int:
    """Host-side key of a python float (for sentinel constants)."""
    b = int(np.float32(x).view(np.int32))
    return b ^ _SIGN_FLIP if b < 0 else b


def mark_new(ids: jnp.ndarray) -> jnp.ndarray:
    """Set the new-entry bit on real ids; -1 sentinels pass through."""
    return jnp.where(ids >= 0, ids | NEW_MARK, ids)


def strip_marks(idw: jnp.ndarray) -> jnp.ndarray:
    """Clear the mark bit.  Guarded on sign so ``-1`` stays ``-1``
    (a bare ``& ~NEW_MARK`` would corrupt the sentinel)."""
    return jnp.where(idw >= 0, idw & ~NEW_MARK, idw)


def is_marked(idw: jnp.ndarray) -> jnp.ndarray:
    return (idw >= 0) & ((idw & NEW_MARK) != 0)


def pack(keys: jnp.ndarray, idw: jnp.ndarray) -> jnp.ndarray:
    """Stack (R, M) key / id-word lanes into the (R, 2, M) sort form."""
    return jnp.stack([keys, idw], axis=1)


def bitonic_desc_packed(x: jnp.ndarray) -> jnp.ndarray:
    """Sort a packed (R, 2, M) array descending by (key, id word).

    M must be a power of two.  The lane ^ jj partner permutation of
    each compare-exchange pass is a reshape + reverse on a length-2
    axis (flip one address bit), which lowers to cheap lane shuffles
    and — unlike gather formulations — keeps compile time flat in the
    network depth.  Both words ride the same ``take_p`` mask: one
    shuffle + one select per pass for the whole record.
    """
    r, two, m = x.shape
    assert two == 2 and m & (m - 1) == 0, (r, two, m)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, 1, m), 2)
    stages = int(np.log2(m))

    def partner(v, jj):
        v5 = v.reshape(r, 2, m // (2 * jj), 2, jj)
        return jnp.flip(v5, axis=3).reshape(r, 2, m)

    for stage in range(1, stages + 1):
        kk = 1 << stage
        for jj in (1 << p for p in range(stage - 1, -1, -1)):
            # keep the max in descending blocks' low lanes and
            # ascending blocks' high lanes
            keep_max = jnp.where((idx & kk) == 0,
                                 (idx & jj) == 0,
                                 (idx & jj) != 0)
            p = partner(x, jj)
            pk, pi = p[:, 0:1], p[:, 1:2]
            xk, xi = x[:, 0:1], x[:, 1:2]
            k_eq = pk == xk
            p_gt = (pk > xk) | (k_eq & (pi > xi))
            p_lt = (pk < xk) | (k_eq & (pi < xi))
            take_p = jnp.where(keep_max, p_gt, p_lt)
            x = jnp.where(take_p, p, x)
    return x


def merge_packed(run: jnp.ndarray, new_keys: jnp.ndarray,
                 new_idw: jnp.ndarray, m_pad: int,
                 *, pad_key: int) -> jnp.ndarray:
    """Merge a packed running (R, 2, K) state with (R, M) candidates.

    Pads the concatenation to ``m_pad`` lanes with (pad_key, -1) and
    returns the full sorted (R, 2, m_pad) network output; callers slice
    the leading K lanes back into their running state.
    """
    ck = jnp.concatenate([run[:, 0], new_keys], axis=1)
    ci = jnp.concatenate([run[:, 1], new_idw], axis=1)
    pad = m_pad - ck.shape[1]
    if pad:
        ck = jnp.pad(ck, ((0, 0), (0, pad)), constant_values=pad_key)
        ci = jnp.pad(ci, ((0, 0), (0, pad)), constant_values=-1)
    return bitonic_desc_packed(pack(ck, ci))
