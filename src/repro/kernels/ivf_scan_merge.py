"""Fused multi-probe IVF scan -> top-k merge kernel (DESIGN §2).

Memory / dispatch model
-----------------------
The unfused hot loop costs two ``pallas_call`` dispatches per probe and
round-trips the raw ``(B, list_pad)`` score tile through HBM between
the scan (``ivf_scan.py``) and the merge (``topk_merge.py``).  This
kernel fuses the paper's whole inner loop — probe -> score -> merge —
over a *chunk* of probes in a single launch, and (optionally) folds the
live-mutation delta-buffer scan in as a second stream:

* grid ``(B, chunk)``; for each query ``i`` the kernel walks its
  ``chunk`` probed clusters one probe per step.
* cluster tiles live in HBM (``memory_space=ANY``) and stream to VMEM
  through a double-buffered ``pltpu.emit_pipeline`` whose block index
  map is the scalar-prefetched ``blk_l``-aligned list offset
  (``build_index(align=...)`` guarantees alignment): the MXU scores
  tile ``t`` while the DMA engine copies tile ``t+1``.  On CPU
  (interpret mode) the same per-tile body runs as an unrolled loop of
  dynamic-slice reads — ``emit_pipeline`` asserts a real TPU at trace
  time, so the ``pipelined`` flag is static.
* raw scores NEVER touch HBM: each ``(blk_l,)`` strip lands in a VMEM
  scratch accumulator; once a probe's ``list_pad`` strip is complete it
  is masked by the true list size and merged into the packed running
  top-k via the shared bitonic network (``kernels/sort.py``): score
  keys in one int32 word, the doc id in the other, so every
  compare-exchange moves one stacked record instead of three lanes.
* the per-probe *new-entry count* — and therefore the patience signal
  ``phi = 100 * (k - new_entries) / k`` — falls out of the merge for
  free: entering candidates carry ``sort.NEW_MARK`` in their id word,
  survivors still marked after the sort are this probe's new entries.
  Marks are stripped before the snapshot is written.
* **delta stream** (live mutation, ``repro.index``): the fixed-capacity
  buffer of freshly added vectors is scored ONCE per query (at the
  chunk's first probe) through a second prefetch pipeline into a VMEM
  strip, then each entry is merged exactly at the probe slot of its
  *assigned* cluster (scalar-prefetched ``gate_cids``; slots past the
  probe budget gate on ``-2`` so they can never match an empty slot's
  ``assign == -1``).  Because the running top-k already carries every
  earlier merge, gating each entry once at its own probe reproduces the
  sequential per-probe reference bit-for-bit — no host-side re-merge.

Outputs per launch: per-probe top-k snapshots ``(B, chunk, k)`` scores
and doc ids (so the caller can evaluate the exit policy at per-probe
granularity and roll a query back to its exact exit probe) plus the
``(B, chunk)`` int32 new-entry counts.  HBM write traffic per probe is
``k`` lanes instead of ``list_pad`` — and the merge reads come from
VMEM instead of HBM.

Scores use the ``-1e30`` sentinel in place of ``-inf`` inside the sort
network; ``ops.ivf_scan_merge`` maps sentinels back to ``-inf`` on the
way out so callers see the same empty-slot convention as the XLA path.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import sort

NEG = -1e30          # finite stand-in for -inf inside the sort network
VALID_MIN = -1e29    # scores above this are real candidates
KEY_NEG = sort.key_of(NEG)
KEY_VALID = sort.key_of(VALID_MIN)


def _score_tiles(docs_ref, ids_ref, bo, sbuf, ibuf, q, *, nblk: int,
                 blk_l: int, d: int, pipelined: bool) -> None:
    """Score ``nblk`` (blk_l, d) tiles starting at block row ``bo``.

    ``docs_ref``/``ids_ref`` live in ANY (HBM) space.  Pipelined: a
    double-buffered ``emit_pipeline`` whose index map adds the
    prefetched block offset, overlapping each tile's DMA with the
    previous tile's MXU dot.  Interpret fallback: the same per-tile
    compute as an unrolled dynamic-slice loop.
    """
    def tile_dot(tile, ids):
        return (jax.lax.dot_general(
            q, tile.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32), ids)

    if pipelined:
        def body(doc_t, id_t):
            t = pl.program_id(0)
            s, ids = tile_dot(doc_t[...], id_t[...])
            sbuf[pl.ds(t, 1)] = s
            ibuf[pl.ds(t, 1)] = ids
        pltpu.emit_pipeline(
            body, grid=(nblk,),
            in_specs=[pl.BlockSpec((blk_l, d), lambda t: (bo + t, 0)),
                      pl.BlockSpec((1, blk_l), lambda t: (bo + t, 0))],
            out_specs=(),
        )(docs_ref, ids_ref)
    else:
        for t in range(nblk):
            tile = docs_ref[pl.ds((bo + t) * blk_l, blk_l), :]
            ids = ids_ref[pl.ds(bo + t, 1), :]
            s, ids = tile_dot(tile, ids)
            sbuf[pl.ds(t, 1)] = s
            ibuf[pl.ds(t, 1)] = ids


def _score_delta(dvec_ref, dsc, q, *, cap_pad: int, blk_dl: int, d: int,
                 pipelined: bool) -> None:
    """Second prefetch stream: score the whole delta buffer into the
    (1, cap_pad) VMEM strip ``dsc`` (done once per query, at the
    chunk's first probe slot)."""
    nblk_d = cap_pad // blk_dl

    def strip_dot(tile):
        return jax.lax.dot_general(
            q, tile.astype(jnp.float32), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    if pipelined:
        def body(dv_t):
            t = pl.program_id(0)
            dsc[:, pl.ds(t * blk_dl, blk_dl)] = strip_dot(dv_t[...])
        pltpu.emit_pipeline(
            body, grid=(nblk_d,),
            in_specs=[pl.BlockSpec((blk_dl, d), lambda t: (t, 0))],
            out_specs=(),
        )(dvec_ref)
    else:
        for t in range(nblk_d):
            tile = dvec_ref[pl.ds(t * blk_dl, blk_dl), :]
            dsc[:, pl.ds(t * blk_dl, blk_dl)] = strip_dot(tile)


def _kernel(*refs, k: int, chunk: int, blk_l: int, nblk: int,
            list_pad: int, m_pad: int, d: int, pipelined: bool,
            has_delta: bool, cap_pad: int, blk_dl: int, m2_pad: int):
    if has_delta:
        (boffs_ref, sizes_ref, gates_ref, q_ref, docs_ref, ids_ref,
         ins_ref, ini_ref, dvec_ref, did_ref, das_ref, outs_ref,
         outi_ref, cnt_ref, sbuf, ibuf, run_p, dsc) = refs
    else:
        (boffs_ref, sizes_ref, q_ref, docs_ref, ids_ref, ins_ref,
         ini_ref, outs_ref, outi_ref, cnt_ref, sbuf, ibuf, run_p) = refs
    i = pl.program_id(0)
    j = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)          # (1, d)

    # chunk start: load this query's incoming running top-k into the
    # packed scratch, and score the delta buffer once
    @pl.when(j == 0)
    def _load_running():
        s0 = jnp.maximum(ins_ref[...], NEG)     # clamp -inf empty slots
        run_p[0:1] = sort.score_to_key(s0)
        run_p[1:2] = ini_ref[...]
        if has_delta:
            _score_delta(dvec_ref, dsc, q, cap_pad=cap_pad,
                         blk_dl=blk_dl, d=d, pipelined=pipelined)

    # stream + score this probe's cluster tile (double-buffered on TPU)
    bo = boffs_ref[i * chunk + j]
    _score_tiles(docs_ref, ids_ref, bo, sbuf, ibuf, q, nblk=nblk,
                 blk_l=blk_l, d=d, pipelined=pipelined)

    # merge A: the probe tile, masked by true list size, NEW-marked
    size = sizes_ref[i * chunk + j]
    new_s = sbuf[...].reshape(1, list_pad)
    new_i = ibuf[...].reshape(1, list_pad)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, list_pad), 1)
    # tombstones: deleted rows keep their vector but their stored id is
    # burned to -1 (repro.index.live), so masking id < 0 hides both
    # padding and deleted docs without an extra input stream
    alive = (lane < size) & (new_i >= 0)
    new_k = jnp.where(alive, sort.score_to_key(new_s), KEY_NEG)
    new_iw = jnp.where(alive, new_i | sort.NEW_MARK, -1)
    res = sort.merge_packed(run_p[...].reshape(1, 2, k), new_k, new_iw,
                            m_pad, pad_key=KEY_NEG)
    run_p[...] = res[0, :, :k]

    if has_delta:
        # merge B: delta entries whose assigned cluster is THIS probe.
        # Each entry is offered exactly once (its own slot); the running
        # top-k already holds every earlier merge, so this reproduces
        # the sequential per-probe reference.
        gate_cid = gates_ref[i * chunk + j]
        das = das_ref[...]                       # (1, cap_pad)
        dio = did_ref[...]                       # (1, cap_pad)
        gate = (das == gate_cid) & (dio >= 0)

        @pl.when(jnp.any(gate))
        def _merge_delta():
            dk = jnp.where(gate, sort.score_to_key(dsc[...]), KEY_NEG)
            diw = jnp.where(gate, dio | sort.NEW_MARK, -1)
            res2 = sort.merge_packed(run_p[...].reshape(1, 2, k), dk,
                                     diw, m2_pad, pad_key=KEY_NEG)
            run_p[...] = res2[0, :, :k]

    # lanes still NEW-marked survived this probe's merge(s):
    # phi = 100 * kept / k = 100 * (k - new_entries) / k
    keys = run_p[0:1, :]
    idw = run_p[1:2, :]
    kept = jnp.sum(((keys > KEY_VALID) & ~sort.is_marked(idw))
                   .astype(jnp.int32))
    cnt_ref[...] = jnp.full((1, 1), k, jnp.int32) - kept
    clean = sort.strip_marks(idw)
    run_p[1:2] = clean
    outs_ref[...] = sort.key_to_score(keys).reshape(1, 1, k)
    outi_ref[...] = clean.reshape(1, 1, k)


def ivf_scan_merge(queries: jnp.ndarray, docs: jnp.ndarray,
                   ids2d: jnp.ndarray, block_offsets: jnp.ndarray,
                   sizes: jnp.ndarray, run_scores: jnp.ndarray,
                   run_ids: jnp.ndarray, *, k: int, list_pad: int,
                   chunk: int, blk_l: int = 64,
                   delta_vecs: Optional[jnp.ndarray] = None,
                   delta_ids: Optional[jnp.ndarray] = None,
                   delta_assign: Optional[jnp.ndarray] = None,
                   gate_cids: Optional[jnp.ndarray] = None,
                   blk_dl: int = 128, pipelined: bool = False,
                   interpret: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """queries (B,d); docs (n,d) cluster-major; ids2d (n//blk_l, blk_l)
    doc ids reshaped row-blocked; block_offsets/sizes (B*chunk,) int32
    (offsets in blk_l units); run_scores/run_ids (B,k) incoming top-k.

    Optional delta stream: delta_vecs (cap_pad, d) with cap_pad a
    ``blk_dl`` multiple, delta_ids/delta_assign (1, cap_pad) int32
    (id -1 = empty slot, assign -2 on padding), gate_cids (B*chunk,)
    int32 — the probed cluster of each slot, or -2 for slots past the
    probe budget.

    ``pipelined`` (static): double-buffered ``emit_pipeline`` tile
    streaming; requires a real TPU (the pipeline emitter asserts the
    target generation at trace time), so interpret mode always runs
    the unrolled dynamic-slice fallback of the same per-tile body.

    Returns per-probe snapshots (B, chunk, k) scores (NEG sentinel for
    empty slots) / ids, and (B, chunk) int32 new-entry counts.
    """
    b, d = queries.shape
    assert list_pad % blk_l == 0, "list_pad must be a blk_l multiple"
    has_delta = delta_vecs is not None
    nblk = list_pad // blk_l
    m_pad = 1 << int(np.ceil(np.log2(k + list_pad)))
    if has_delta:
        cap_pad = delta_vecs.shape[0]
        assert cap_pad % blk_dl == 0, "delta cap must be blk_dl-padded"
        m2_pad = 1 << int(np.ceil(np.log2(k + cap_pad)))
    else:
        cap_pad, m2_pad = 0, 0
    npf = 3 if has_delta else 2      # trailing scalar-prefetch ref args

    def at_query(i, j, *_):
        return (i, 0)

    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    in_specs = [
        pl.BlockSpec((1, d), at_query),          # queries
        any_spec,                                # docs (HBM, pipelined)
        any_spec,                                # ids2d
        pl.BlockSpec((1, k), at_query),          # run_scores
        pl.BlockSpec((1, k), at_query),          # run_ids
    ]
    inputs = [queries, docs, ids2d, run_scores, run_ids]
    if has_delta:
        in_specs += [
            any_spec,                            # delta vecs (HBM)
            pl.BlockSpec((1, cap_pad), lambda *_: (0, 0)),
            pl.BlockSpec((1, cap_pad), lambda *_: (0, 0)),
        ]
        inputs += [delta_vecs, delta_ids.reshape(1, cap_pad),
                   delta_assign.reshape(1, cap_pad)]
    scratch = [
        pltpu.VMEM((nblk, blk_l), jnp.float32),  # probe score strip
        pltpu.VMEM((nblk, blk_l), jnp.int32),    # probe id strip
        pltpu.VMEM((2, k), jnp.int32),           # packed running top-k
    ]
    if has_delta:
        scratch.append(pltpu.VMEM((1, cap_pad), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=npf,
        grid=(b, chunk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda i, j, *_: (i, j, 0)),
            pl.BlockSpec((1, 1, k), lambda i, j, *_: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, *_: (i, j)),
        ],
        scratch_shapes=scratch,
    )
    kern = functools.partial(
        _kernel, k=k, chunk=chunk, blk_l=blk_l, nblk=nblk,
        list_pad=list_pad, m_pad=m_pad, d=d, pipelined=pipelined,
        has_delta=has_delta, cap_pad=cap_pad, blk_dl=blk_dl,
        m2_pad=m2_pad)
    prefetch = [block_offsets.astype(jnp.int32), sizes.astype(jnp.int32)]
    if has_delta:
        prefetch.append(gate_cids.astype(jnp.int32))
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, chunk, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, chunk, k), jnp.int32),
                   jax.ShapeDtypeStruct((b, chunk), jnp.int32)],
        interpret=interpret,
    )(*prefetch, *inputs)
