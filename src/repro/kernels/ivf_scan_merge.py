"""Fused multi-probe IVF scan -> top-k merge kernel (DESIGN §2).

Memory / dispatch model
-----------------------
The unfused hot loop costs two ``pallas_call`` dispatches per probe and
round-trips the raw ``(B, list_pad)`` score tile through HBM between the
scan (``ivf_scan.py``) and the merge (``topk_merge.py``).  This kernel
fuses the paper's whole inner loop — probe -> score -> merge — over a
*chunk* of probes in a single launch:

* grid ``(B, chunk, list_pad // blk_l)``; the last dimension is
  innermost, so for each query ``i`` the kernel walks its ``chunk``
  probed clusters tile by tile.
* per-(query, probe) cluster tiles stream HBM -> VMEM via
  scalar-prefetched block offsets (``PrefetchScalarGridSpec``), so the
  DMA engine fetches probe ``j+1``'s tile while the MXU scores probe
  ``j``.  Offsets must be ``blk_l``-aligned (``build_index(align=...)``
  guarantees it).
* raw scores NEVER touch HBM: each ``(blk_l,)`` score strip lands in a
  VMEM scratch accumulator; once a probe's ``list_pad`` strip is
  complete it is masked by the true list size and bitonic-merged into a
  running top-k held in VMEM scratch for the whole chunk.
* every running-top-k lane carries the probe index it entered on
  (``tag``; -1 for candidates inherited from the incoming running
  top-k), so the per-probe *new-entry count* — and therefore the
  patience stability signal ``phi = 100 * (k - new_entries) / k`` —
  falls out of the merge for free, with no ``intersection_pct``
  re-computation on (B, k) id sets.

Outputs per launch: per-probe top-k snapshots ``(B, chunk, k)`` scores
and doc ids (so the caller can evaluate the exit policy at per-probe
granularity and roll a query back to its exact exit probe) plus the
``(B, chunk)`` int32 new-entry counts.  HBM write traffic per probe is
``k`` lanes instead of ``list_pad`` — and the merge reads come from
VMEM instead of HBM.

Scores use the ``-1e30`` sentinel in place of ``-inf`` inside the sort
network; ``ops.ivf_scan_merge`` maps sentinels back to ``-inf`` on the
way out so callers see the same empty-slot convention as the XLA path.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30          # finite stand-in for -inf inside the sort network
VALID_MIN = -1e29    # scores above this are real candidates


def _bitonic_desc_tagged(s: jnp.ndarray, i: jnp.ndarray, t: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sort rows of s (R, M) descending, carrying ids i and tags t.

    M must be a power of two.  The XOR-partner permutation of each
    compare-exchange pass is expressed as a reshape + reverse on a
    length-2 axis (lane ^ jj flips one address bit), which lowers to
    cheap lane shuffles and — unlike gather-based formulations — keeps
    XLA/Mosaic compile time flat in the network depth.
    """
    r, m = s.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    stages = int(np.log2(m))

    def partner(x, jj):
        x3 = x.reshape(r, m // (2 * jj), 2, jj)
        return jnp.flip(x3, axis=2).reshape(r, m)

    for stage in range(1, stages + 1):
        kk = 1 << stage
        for jj in (1 << p for p in range(stage - 1, -1, -1)):
            # per-lane mask: keep the max in descending blocks' low
            # lanes and ascending blocks' high lanes
            keep_max = jnp.where((idx & kk) == 0,
                                 (idx & jj) == 0,
                                 (idx & jj) != 0)
            ps, pi, pt = partner(s, jj), partner(i, jj), partner(t, jj)
            take_p = jnp.where(keep_max, ps > s, ps < s)
            s = jnp.where(take_p, ps, s)
            i = jnp.where(take_p, pi, i)
            t = jnp.where(take_p, pt, t)
    return s, i, t


def _kernel(boffs_ref, sizes_ref, q_ref, docs_ref, ids_ref, ins_ref,
            ini_ref, outs_ref, outi_ref, cnt_ref, sbuf, ibuf, ts, ti, tt,
            *, k: int, chunk: int, blk_l: int, nblk: int, list_pad: int,
            m_pad: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    tile_idx = pl.program_id(2)

    # chunk start: load this query's incoming running top-k into scratch
    @pl.when((j == 0) & (tile_idx == 0))
    def _load_running():
        s0 = jnp.pad(ins_ref[...], ((0, 0), (0, m_pad - k)),
                     constant_values=NEG)
        ts[...] = jnp.maximum(s0, NEG)          # clamp -inf empty slots
        ti[...] = jnp.pad(ini_ref[...], ((0, 0), (0, m_pad - k)),
                          constant_values=-1)
        tt[...] = jnp.full((1, m_pad), -1, jnp.int32)

    # score one (blk_l, d) strip of the probed cluster on the MXU
    q = q_ref[...].astype(jnp.float32)          # (1, d)
    tile = docs_ref[...].astype(jnp.float32)    # (blk_l, d)
    sbuf[pl.ds(tile_idx, 1)] = jax.lax.dot_general(
        q, tile, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (1, blk_l)
    ibuf[pl.ds(tile_idx, 1)] = ids_ref[...]

    # full probe tile scored: mask by list size and merge into top-k
    @pl.when(tile_idx == nblk - 1)
    def _merge():
        size = sizes_ref[i * chunk + j]
        new_s = sbuf[...].reshape(1, list_pad)
        new_i = ibuf[...].reshape(1, list_pad)
        lane = jax.lax.broadcasted_iota(jnp.int32, (1, list_pad), 1)
        in_list = lane < size
        new_i = jnp.where(in_list, new_i, -1)
        # tombstones: deleted rows keep their vector but their stored id
        # is burned to -1 (repro.index.live), so masking id < 0 hides
        # both padding and deleted docs without an extra input stream
        alive = in_list & (new_i >= 0)
        new_s = jnp.where(alive, new_s, NEG)
        new_t = jnp.where(alive, j, -1)
        cand_s = jnp.concatenate([ts[:, :k], new_s], axis=1)
        cand_i = jnp.concatenate([ti[:, :k], new_i], axis=1)
        cand_t = jnp.concatenate([tt[:, :k], new_t], axis=1)
        pad = m_pad - (k + list_pad)
        if pad:
            cand_s = jnp.pad(cand_s, ((0, 0), (0, pad)),
                             constant_values=NEG)
            cand_i = jnp.pad(cand_i, ((0, 0), (0, pad)),
                             constant_values=-1)
            cand_t = jnp.pad(cand_t, ((0, 0), (0, pad)),
                             constant_values=-1)
        ss, si, st = _bitonic_desc_tagged(cand_s, cand_i, cand_t)
        ts[...] = ss
        ti[...] = si
        tt[...] = st
        # lanes that survived from before this probe == |prev ∩ new|;
        # phi = 100 * kept / k = 100 * (k - new_entries) / k
        kept = jnp.sum(((ss[:, :k] > VALID_MIN) & (st[:, :k] < j))
                       .astype(jnp.int32))
        cnt_ref[...] = jnp.full((1, 1), k, jnp.int32) - kept
        outs_ref[...] = ss[:, :k].reshape(1, 1, k)
        outi_ref[...] = si[:, :k].reshape(1, 1, k)


def ivf_scan_merge(queries: jnp.ndarray, docs: jnp.ndarray,
                   ids2d: jnp.ndarray, block_offsets: jnp.ndarray,
                   sizes: jnp.ndarray, run_scores: jnp.ndarray,
                   run_ids: jnp.ndarray, *, k: int, list_pad: int,
                   chunk: int, blk_l: int = 64, interpret: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """queries (B,d); docs (n,d) cluster-major; ids2d (n//blk_l, blk_l)
    doc ids reshaped row-blocked; block_offsets/sizes (B*chunk,) int32
    (offsets in blk_l units); run_scores/run_ids (B,k) incoming top-k.

    Returns per-probe snapshots (B, chunk, k) scores (NEG sentinel for
    empty slots) / ids, and (B, chunk) int32 new-entry counts.
    """
    b, d = queries.shape
    assert list_pad % blk_l == 0, "list_pad must be a blk_l multiple"
    nblk = list_pad // blk_l
    m_pad = 1 << int(np.ceil(np.log2(k + list_pad)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, chunk, nblk),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, l, bo, sz: (i, 0)),
            pl.BlockSpec((blk_l, d),
                         lambda i, j, l, bo, sz: (bo[i * chunk + j] + l, 0)),
            pl.BlockSpec((1, blk_l),
                         lambda i, j, l, bo, sz: (bo[i * chunk + j] + l, 0)),
            pl.BlockSpec((1, k), lambda i, j, l, bo, sz: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j, l, bo, sz: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda i, j, l, bo, sz: (i, j, 0)),
            pl.BlockSpec((1, 1, k), lambda i, j, l, bo, sz: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, l, bo, sz: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((nblk, blk_l), jnp.float32),   # probe score strip
            pltpu.VMEM((nblk, blk_l), jnp.int32),     # probe id strip
            pltpu.VMEM((1, m_pad), jnp.float32),      # running top-k scores
            pltpu.VMEM((1, m_pad), jnp.int32),        # running top-k ids
            pltpu.VMEM((1, m_pad), jnp.int32),        # entry-probe tags
        ],
    )
    kern = functools.partial(_kernel, k=k, chunk=chunk, blk_l=blk_l,
                             nblk=nblk, list_pad=list_pad, m_pad=m_pad)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, chunk, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, chunk, k), jnp.int32),
                   jax.ShapeDtypeStruct((b, chunk), jnp.int32)],
        interpret=interpret,
    )(block_offsets.astype(jnp.int32), sizes.astype(jnp.int32),
      queries, docs, ids2d, run_scores, run_ids)
