"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q,k,v: (BH, S, hd) -> (BH, S, hd). Plain masked softmax."""
    s = q.shape[1]
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)) \
        .astype(q.dtype)


def ivf_scan_ref(queries: jnp.ndarray, docs: jnp.ndarray,
                 offsets: jnp.ndarray, sizes: jnp.ndarray,
                 list_pad: int) -> jnp.ndarray:
    """(B,d) x cluster-major (n,d) rows [offset, offset+size) ->
    (B, list_pad) scores, -inf outside the list."""
    tiles = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
        docs, o, list_pad, 0))(offsets)
    sc = jnp.einsum("bld,bd->bl", tiles.astype(jnp.float32),
                    queries.astype(jnp.float32))
    mask = jnp.arange(list_pad)[None] < sizes[:, None]
    return jnp.where(mask, sc, -jnp.inf)


def topk_merge_ref(scores: jnp.ndarray, ids: jnp.ndarray,
                   new_scores: jnp.ndarray, new_ids: jnp.ndarray,
                   k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cat_s = jnp.concatenate([scores, new_scores], 1)
    cat_i = jnp.concatenate([ids, new_ids], 1)
    ts, idx = jax.lax.top_k(cat_s, k)
    return ts, jnp.take_along_axis(cat_i, idx, 1)


def ivf_scan_merge_ref(queries: jnp.ndarray, docs: jnp.ndarray,
                       doc_ids: jnp.ndarray, offsets: jnp.ndarray,
                       sizes: jnp.ndarray, run_scores: jnp.ndarray,
                       run_ids: jnp.ndarray, k: int, list_pad: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused multi-probe scan+merge oracle.

    offsets/sizes: (B, chunk) row offsets / true list sizes of each
    query's probed clusters.  run_scores/run_ids: (B, k) incoming
    running top-k (-inf / -1 empty slots).  Returns per-probe top-k
    snapshots (B, chunk, k) scores / ids and (B, chunk) int32
    new-entry counts, where count = k - |prev_topk ∩ new_topk|
    (invalid slots count as new), so
    phi = 100 * (k - count) / k == intersection_pct(prev, new).
    """
    chunk = offsets.shape[1]
    s, i = run_scores.astype(jnp.float32), run_ids
    snap_s, snap_i, cnts = [], [], []
    for t in range(chunk):
        tiles = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
            docs, o, list_pad, 0))(offsets[:, t])
        tids = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
            doc_ids, o, list_pad, 0))(offsets[:, t])
        sc = jnp.einsum("bld,bd->bl", tiles.astype(jnp.float32),
                        queries.astype(jnp.float32))
        mask = jnp.arange(list_pad)[None] < sizes[:, t][:, None]
        tids = jnp.where(mask, tids, -1)
        # id < 0 == padding or tombstoned row: never a candidate
        sc = jnp.where(mask & (tids >= 0), sc, -jnp.inf)
        ns, ni = topk_merge_ref(s, i, sc, tids, k)
        inter = jnp.sum((i[:, :, None] == ni[:, None, :])
                        & (i[:, :, None] >= 0), axis=(1, 2))
        cnts.append(k - inter.astype(jnp.int32))
        snap_s.append(ns)
        snap_i.append(ni)
        s, i = ns, ni
    return (jnp.stack(snap_s, axis=1), jnp.stack(snap_i, axis=1),
            jnp.stack(cnts, axis=1))


def delta_scan_ref(queries: jnp.ndarray, vecs: jnp.ndarray) -> jnp.ndarray:
    """queries (B,d) x delta vecs (cap,d) -> (B,cap) raw f32 scores."""
    return queries.astype(jnp.float32) @ vecs.astype(jnp.float32).T


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """table (R,D), ids (B,F) -> (B,D) sum-bag."""
    return jnp.take(table, ids, axis=0).sum(axis=1)
