"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True) -> jnp.ndarray:
    """q,k,v: (BH, S, hd) -> (BH, S, hd). Plain masked softmax."""
    s = q.shape[1]
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)) \
        .astype(q.dtype)


def ivf_scan_ref(queries: jnp.ndarray, docs: jnp.ndarray,
                 offsets: jnp.ndarray, sizes: jnp.ndarray,
                 list_pad: int) -> jnp.ndarray:
    """(B,d) x cluster-major (n,d) rows [offset, offset+size) ->
    (B, list_pad) scores, -inf outside the list."""
    tiles = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
        docs, o, list_pad, 0))(offsets)
    sc = jnp.einsum("bld,bd->bl", tiles.astype(jnp.float32),
                    queries.astype(jnp.float32))
    mask = jnp.arange(list_pad)[None] < sizes[:, None]
    return jnp.where(mask, sc, -jnp.inf)


def topk_merge_ref(scores: jnp.ndarray, ids: jnp.ndarray,
                   new_scores: jnp.ndarray, new_ids: jnp.ndarray,
                   k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cat_s = jnp.concatenate([scores, new_scores], 1)
    cat_i = jnp.concatenate([ids, new_ids], 1)
    ts, idx = jax.lax.top_k(cat_s, k)
    return ts, jnp.take_along_axis(cat_i, idx, 1)


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """table (R,D), ids (B,F) -> (B,D) sum-bag."""
    return jnp.take(table, ids, axis=0).sum(axis=1)
