"""EmbeddingBag gather-reduce kernel (FBGEMM-TBE pattern in Pallas).

RecSys hot path: ids (B, F) -> sum of F table rows per bag. Grid is
(B, F); the row BlockSpec's index_map reads the prefetched id table, so
each grid step DMA's exactly one (1, D) row HBM->VMEM; the output bag
block is revisited across the F steps and accumulated in place
(initialised on the first visit). No one-hot matmul, no (B, F, D)
intermediate in HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, row_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += row_ref[...].astype(o_ref.dtype)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray, *,
                  interpret: bool = False) -> jnp.ndarray:
    """table (R, D); ids (B, F) int32 -> (B, D) sum-combined bags."""
    b, f = ids.shape
    r, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, f),
        in_specs=[pl.BlockSpec((1, d), lambda i, j, ids: (ids[i, j], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, j, ids: (i, 0)),
    )
    return pl.pallas_call(
        _kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
