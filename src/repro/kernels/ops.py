"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in interpret mode — the
kernel body executes eagerly in Python for correctness validation
against ref.py. On a TPU backend the same call sites compile the real
Mosaic kernels.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import delta_scan as _ds
from repro.kernels import embedding_bag as _eb
from repro.kernels import flash_attention as _fa
from repro.kernels import ivf_scan as _scan
from repro.kernels import ivf_scan_merge as _sm
from repro.kernels import topk_merge as _tm


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("list_pad", "blk_l"))
def ivf_scan(queries, docs, offsets, sizes, *, list_pad: int,
             blk_l: int = 64):
    """Fused cluster-tile scoring; -inf outside each true list size."""
    raw = _scan.ivf_scan(queries, docs, offsets, list_pad=list_pad,
                         blk_l=blk_l, interpret=_interpret())
    mask = jnp.arange(list_pad)[None, :] < sizes[:, None]
    return jnp.where(mask, raw, -jnp.inf)


@functools.partial(
    jax.jit, static_argnames=("k", "list_pad", "chunk", "blk_l",
                              "blk_dl"))
def ivf_scan_merge(queries, docs, doc_ids, offsets, sizes, run_scores,
                   run_ids, delta_vecs=None, delta_ids=None,
                   delta_assign=None, gate_cids=None, *, k: int,
                   list_pad: int, chunk: int, blk_l: int = 64,
                   blk_dl: int = 128
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused multi-probe scan -> running top-k merge (one dispatch per
    ``chunk`` probes; see ivf_scan_merge.py for the memory model).

    offsets/sizes: (B, chunk) row offsets (blk_l aligned) and true list
    sizes per probed cluster; run_scores/run_ids: (B, k) incoming
    running top-k.  Returns ((B, chunk, k) snapshot scores with -inf
    empty slots, (B, chunk, k) snapshot ids, (B, chunk) new-entry
    counts with phi = 100 * (k - count) / k).

    Live-mutation overlay (all four together or none): delta_vecs
    (cap, d) / delta_ids / delta_assign (cap,) — the delta buffer, id
    -1 on empty or tombstoned slots — and gate_cids (B, chunk), the
    probed cluster id of each slot or -2 for slots past the probe
    budget.  The buffer is scored in-kernel as a second prefetch
    stream and each entry merges at its assigned cluster's probe slot,
    so the counts (and phi) stay exact — one Pallas dispatch per
    chunk, no host-side re-merge.
    """
    n = doc_ids.shape[0]
    tail = (-n) % blk_l
    ids2d = jnp.pad(doc_ids, (0, tail),
                    constant_values=-1).reshape(-1, blk_l)
    kw = {}
    if delta_vecs is not None:
        cap = delta_vecs.shape[0]
        blk_dl = min(blk_dl, 1 << int(np.ceil(np.log2(max(cap, 1)))))
        dtail = (-cap) % blk_dl
        kw = dict(
            delta_vecs=jnp.pad(delta_vecs, ((0, dtail), (0, 0))),
            delta_ids=jnp.pad(delta_ids, (0, dtail),
                              constant_values=-1),
            delta_assign=jnp.pad(delta_assign, (0, dtail),
                                 constant_values=-2),
            gate_cids=gate_cids.reshape(-1), blk_dl=blk_dl)
    out_s, out_i, cnt = _sm.ivf_scan_merge(
        queries, docs, ids2d,
        (offsets // blk_l).reshape(-1), sizes.reshape(-1),
        run_scores, run_ids, k=k, list_pad=list_pad, chunk=chunk,
        blk_l=blk_l, pipelined=not _interpret(),
        interpret=_interpret(), **kw)
    # sentinel -> -inf so empty slots match the XLA merge convention
    out_s = jnp.where(out_s > _sm.VALID_MIN, out_s, -jnp.inf)
    return out_s, out_i, cnt


@functools.partial(jax.jit, static_argnames=("blk_b", "blk_c"))
def delta_scan(queries, vecs, *, blk_b: int = 8, blk_c: int = 128):
    """Brute-force scan of the live-mutation delta buffer: (B,d) x
    (cap,d) -> (B,cap) raw scores (callers mask empty/tombstoned slots
    by ``ids >= 0``)."""
    return _ds.delta_scan(queries, vecs, blk_b=blk_b, blk_c=blk_c,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("k", "blk_b"))
def topk_merge(scores, ids, new_scores, new_ids, k: int, *,
               blk_b: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _tm.topk_merge(scores, ids, new_scores, new_ids, k,
                          blk_b=blk_b, interpret=_interpret())


@jax.jit
def embedding_bag(table, ids):
    return _eb.embedding_bag(table, ids, interpret=_interpret())
