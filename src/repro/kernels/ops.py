"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) every kernel runs in interpret mode — the
kernel body executes eagerly in Python for correctness validation
against ref.py. On a TPU backend the same call sites compile the real
Mosaic kernels.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import embedding_bag as _eb
from repro.kernels import flash_attention as _fa
from repro.kernels import ivf_scan as _scan
from repro.kernels import topk_merge as _tm


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, blk_q=blk_q,
                               blk_k=blk_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("list_pad", "blk_l"))
def ivf_scan(queries, docs, offsets, sizes, *, list_pad: int,
             blk_l: int = 64):
    """Fused cluster-tile scoring; -inf outside each true list size."""
    raw = _scan.ivf_scan(queries, docs, offsets, list_pad=list_pad,
                         blk_l=blk_l, interpret=_interpret())
    mask = jnp.arange(list_pad)[None, :] < sizes[:, None]
    return jnp.where(mask, raw, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("k", "blk_b"))
def topk_merge(scores, ids, new_scores, new_ids, k: int, *,
               blk_b: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _tm.topk_merge(scores, ids, new_scores, new_ids, k,
                          blk_b=blk_b, interpret=_interpret())


@jax.jit
def embedding_bag(table, ids):
    return _eb.embedding_bag(table, ids, interpret=_interpret())
