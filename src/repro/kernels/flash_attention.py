"""Causal flash attention (forward) — Pallas TPU kernel.

Online-softmax over streamed KV blocks (FlashAttention, arXiv:2205.14135
adapted to the TPU memory hierarchy): grid (BH, n_q, n_k); the (blk_q,
hd) query tile and running (m, l, acc) stats live in VMEM scratch; each
grid step DMA's one (blk_k, hd) KV tile HBM->VMEM and updates the stats;
the output tile is written once on the last KV step. Causal blocks above
the diagonal are skipped via pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, blk_q: int, blk_k: int, causal: bool, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * blk_k <= i * blk_q + blk_q - 1) if causal else True

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (blk_q, hd)
        k = k_ref[0].astype(jnp.float32)                 # (blk_k, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 0)
            kpos = j * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]) \
            .astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool = False
                    ) -> jnp.ndarray:
    """q,k,v: (BH, S, hd) -> (BH, S, hd)."""
    bh, s, hd = q.shape
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, s)
    assert s % blk_q == 0 and s % blk_k == 0
    grid = (bh, s // blk_q, s // blk_k)
    scale = 1.0 / np.sqrt(hd)
    kern = functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k,
                             causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
