"""IVF cluster-scan kernel: the paper's hot loop on TPU (DESIGN §2).

Each query streams its probed cluster's contiguous (list_pad, d) tile
from the cluster-major doc matrix straight into VMEM — the per-query row
offset rides in scalar-prefetch (pltpu.PrefetchScalarGridSpec), so the
DMA pipeline can prefetch the next tile while the MXU scores the current
one. Offsets must be aligned to ``blk_l`` rows (build_index(align=...)
guarantees this); masking by true list size happens in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(offs_ref, q_ref, docs_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # (1, d)
    tile = docs_ref[...].astype(jnp.float32)    # (blk_l, d)
    o_ref[...] = jax.lax.dot_general(
        q, tile, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (1, blk_l)


def ivf_scan(queries: jnp.ndarray, docs: jnp.ndarray,
             offsets: jnp.ndarray, *, list_pad: int, blk_l: int = 64,
             interpret: bool = False) -> jnp.ndarray:
    """queries (B,d) f32; docs (n,d) cluster-major; offsets (B,) int32
    (aligned to blk_l) -> raw scores (B, list_pad)."""
    b, d = queries.shape
    assert list_pad % blk_l == 0
    nblk = list_pad // blk_l
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nblk),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, offs: (i, 0)),
            pl.BlockSpec((blk_l, d),
                         lambda i, j, offs: (offs[i] // blk_l + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_l), lambda i, j, offs: (i, j)),
    )
    block_offsets = offsets.astype(jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, list_pad), jnp.float32),
        interpret=interpret,
    )(block_offsets, queries, docs)
