"""Pallas TPU kernels for the perf-critical layers (+ jnp oracles)."""
