"""Brute-force delta-buffer scan kernel (live-mutation subsystem).

The delta buffer holds at most a few thousand recently-added vectors,
so scanning it is one small ``(B, d) x (d, cap)`` matmul.  It still
goes through Pallas so the TPU serving path keeps a single dispatch
discipline: queries and delta tiles stream HBM -> VMEM block by block
and the MXU scores a ``(blk_b, blk_c)`` output tile per grid step.

The kernel returns *raw* scores for every slot (including empty or
tombstoned ones); callers mask by ``DeltaView.ids >= 0`` and by the
per-probe cluster-assignment gate (see ``repro.index``), which is what
keeps live-search results bit-identical to a rebuilt index.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, v_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)          # (blk_b, d)
    v = v_ref[...].astype(jnp.float32)          # (blk_c, d)
    o_ref[...] = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (blk_b, blk_c)


def delta_scan(queries: jnp.ndarray, vecs: jnp.ndarray, *,
               blk_b: int = 8, blk_c: int = 128,
               interpret: bool = False) -> jnp.ndarray:
    """queries (B, d) x delta vecs (cap, d) -> (B, cap) f32 scores."""
    b, d = queries.shape
    cap = vecs.shape[0]
    blk_b = min(blk_b, b)
    blk_c = min(blk_c, cap)
    bp = -(-b // blk_b) * blk_b
    cp = -(-cap // blk_c) * blk_c
    if bp != b:
        queries = jnp.pad(queries, ((0, bp - b), (0, 0)))
    if cp != cap:
        vecs = jnp.pad(vecs, ((0, cp - cap), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=(bp // blk_b, cp // blk_c),
        in_specs=[
            pl.BlockSpec((blk_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((blk_c, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((blk_b, blk_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, cp), jnp.float32),
        interpret=interpret,
    )(queries, vecs)
    return out[:b, :cap]
