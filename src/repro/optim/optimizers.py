"""Optimizers (optax-style pure transforms, no external deps).

AdamW keeps fp32 moments sharded identically to the params (ZeRO-3-like
under the FSDP rules in ``repro.distributed.sharding``). Adafactor is
provided for memory-tight cells (factored second moment).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jnp.ndarray], Tuple[Pytree, Pytree]]
    # update(grads, state, params, step) -> (new_params, new_state)


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01, max_grad_norm: float = 1.0
          ) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"mu": z, "nu": jax.tree.map(jnp.zeros_like, z)}

    def update(grads, state, params, step):
        grads = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            step_ = lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay
                            * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_m, "nu": new_v}

    return Optimizer(init, update)


def adafactor(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
              decay: float = 0.8, eps: float = 1e-30,
              max_grad_norm: float = 1.0) -> Optimizer:
    """Factored second moment for >=2D params (memory ~ sum of dims)."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return jax.tree.map(st, params)

    def update(grads, state, params, step):
        grads = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                r = beta * s["r"] + (1 - beta) * g2.mean(-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(-2)
                denom = (r[..., None] * c[..., None, :]
                         / jnp.maximum(r.mean(-1, keepdims=True)[..., None],
                                       eps))
                u = g / jnp.sqrt(denom + eps)
                ns = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                ns = {"v": v}
            # update clipping (Shazeer & Stern)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), ns

        out = jax.tree.map(upd, grads, state, params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("r" in x or "v" in x))
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_s

    return Optimizer(init, update)


def sgdm(lr: float, momentum: float = 0.9,
         max_grad_norm: float = 0.0) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, step):
        if max_grad_norm:
            grads = clip_by_global_norm(grads, max_grad_norm)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state, params)
        return (jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple)),
                jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple)))

    return Optimizer(init, update)


def warmup_cosine(peak: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * (step + 1) / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 *
                      (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn
