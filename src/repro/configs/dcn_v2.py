"""DCN-v2 — cross network v2 + deep MLP. [arXiv:2008.13535; paper]

n_dense=13 n_sparse=26 embed_dim=16 n_cross=3 mlp=1024-1024-512.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig, register

MODEL = RecsysConfig(name="dcn-v2", n_dense=13, n_sparse=26, embed_dim=16,
                     rows_per_field=1_000_000, mlp=(1024, 1024, 512),
                     interaction="cross", n_cross_layers=3)

SPEC = register(ArchSpec("dcn-v2", "recsys", MODEL, RECSYS_SHAPES,
                         source="arXiv:2008.13535"))
