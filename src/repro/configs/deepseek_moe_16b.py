"""DeepSeekMoE-16B — fine-grained MoE: 2 shared + 64 routed top-6.

[arXiv:2401.06066; hf] 28L d_model=2048 16H d_ff_expert=1408 vocab=102400.
First layer dense FFN (d_ff=10944), paper-faithful.
"""
from repro.configs.base import (ArchSpec, LM_SHAPES, MoEConfig,
                                TransformerConfig, register)

MODEL = TransformerConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=10944, vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  first_k_dense=1, d_ff_dense=10944))

SPEC = register(ArchSpec("deepseek-moe-16b", "lm", MODEL, LM_SHAPES,
                         source="arXiv:2401.06066"))
