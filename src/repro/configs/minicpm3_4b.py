"""MiniCPM3-4B — dense LM with Multi-head Latent Attention.

[hf:openbmb/MiniCPM3-4B; hf] 62L d_model=2560 40H (kv=40 via MLA latent)
d_ff=6400 vocab=73448.
"""
from repro.configs.base import (ArchSpec, LM_SHAPES, MLAConfig,
                                TransformerConfig, register)

MODEL = TransformerConfig(
    name="minicpm3-4b", n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    d_head=96, rope_theta=10000.0, tie_embeddings=True)

SPEC = register(ArchSpec("minicpm3-4b", "lm", MODEL, LM_SHAPES,
                         source="hf:openbmb/MiniCPM3-4B"))
