"""DBRX-132B — MoE: 16 experts top-4, GQA kv=8.

[hf:databricks/dbrx-base; unverified] 40L d_model=6144 48H d_ff_expert=10752
vocab=100352.
"""
from repro.configs.base import (ArchSpec, LM_SHAPES, MoEConfig,
                                TransformerConfig, register)

MODEL = TransformerConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352, rope_theta=500_000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752))

SPEC = register(ArchSpec("dbrx-132b", "lm", MODEL, LM_SHAPES,
                         source="hf:databricks/dbrx-base"))
