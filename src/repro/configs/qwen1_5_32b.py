"""Qwen1.5-32B — dense LM, MHA (kv=40) with QKV bias.

[hf:Qwen/Qwen1.5 family; hf] 64L d_model=5120 40H d_ff=27392 vocab=152064.
decode cells use int8 KV cache (DESIGN §4: 5.5TB bf16 cache at decode_32k).
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig, register

MODEL = TransformerConfig(
    name="qwen1.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0,
    kv_cache_dtype="int8")

SPEC = register(ArchSpec("qwen1.5-32b", "lm", MODEL, LM_SHAPES,
                         source="hf:Qwen/Qwen1.5-32B"))
