"""Two-tower retrieval — sampled-softmax retrieval; the paper's technique
serves the 1M-candidate `retrieval_cand` cell via IVF early-exit.

[RecSys'19 (YouTube); unverified] embed_dim=256 tower 1024-512-256 dot.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig, register

MODEL = RecsysConfig(name="two-tower-retrieval", n_sparse=16, embed_dim=256,
                     rows_per_field=1_000_000, mlp=(),
                     tower_mlp=(1024, 512, 256), interaction="dot",
                     n_candidates=1_000_000)

SPEC = register(ArchSpec("two-tower-retrieval", "recsys", MODEL, RECSYS_SHAPES,
                         source="RecSys'19 YouTube"))
