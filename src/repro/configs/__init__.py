from repro.configs.base import (ArchSpec, GNNConfig, MLAConfig, MoEConfig,
                                RecsysConfig, RetrievalConfig, ShapeSpec,
                                TransformerConfig, get_arch, list_archs,
                                reduced, register, shape_for)
