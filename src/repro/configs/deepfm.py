"""DeepFM — FM interaction + deep MLP over 39 sparse fields.

[arXiv:1703.04247; paper] embed_dim=10 mlp=400-400-400.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig, register

MODEL = RecsysConfig(name="deepfm", n_sparse=39, embed_dim=10,
                     rows_per_field=1_000_000, mlp=(400, 400, 400),
                     interaction="fm")

SPEC = register(ArchSpec("deepfm", "recsys", MODEL, RECSYS_SHAPES,
                         source="arXiv:1703.04247"))
