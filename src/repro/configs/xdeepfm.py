"""xDeepFM — CIN interaction + deep MLP. [arXiv:1803.05170; paper]

n_sparse=39 embed_dim=10 cin=200-200-200 mlp=400-400.
"""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, RecsysConfig, register

MODEL = RecsysConfig(name="xdeepfm", n_sparse=39, embed_dim=10,
                     rows_per_field=1_000_000, mlp=(400, 400),
                     interaction="cin", cin_layers=(200, 200, 200))

SPEC = register(ArchSpec("xdeepfm", "recsys", MODEL, RECSYS_SHAPES,
                         source="arXiv:1803.05170"))
