"""Config system: typed model configs, shape specs, and the arch registry.

Every assigned architecture registers an :class:`ArchSpec` mapping
``--arch <id>`` to (model config, shape set, family). Shapes carry the
*global* batch/sequence dims; sharding rules live in
``repro.distributed.sharding``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8                # routed experts
    top_k: int = 2
    n_shared: int = 0                 # always-on shared experts (DeepSeekMoE)
    d_ff_expert: int = 0              # per-expert hidden dim
    capacity_factor: float = 1.25
    first_k_dense: int = 0            # leading dense-FFN layers (DeepSeekMoE=1)
    d_ff_dense: int = 0               # hidden dim of those dense layers
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                   # 0 -> d_model // n_heads
    qkv_bias: bool = False
    attn_type: str = "gqa"            # "gqa" | "mla"
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    mlp_type: str = "swiglu"          # "swiglu" | "gelu" (2-matrix)
    tie_embeddings: bool = False
    # serving knobs
    kv_cache_dtype: str = "bfloat16"  # "bfloat16" | "int8"
    attn_chunk: int = 512             # query-block size for chunked attention
    remat_policy: str = "nothing"     # "nothing" | "dots" (§Perf: trade
                                      # HBM for fewer recompute gathers)
    param_dtype: str = "float32"      # "bfloat16" halves FSDP gather
                                      # bytes (fp32 lives in the moments)
    # TP padding (see DESIGN §4): heads padded so n_heads % tp == 0
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.head_dim()
        if self.attn_type == "mla":
            m = self.mla or MLAConfig()
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        nmat = 3 if self.mlp_type == "swiglu" else 2
        if self.moe is not None:
            mo = self.moe
            ff_layer = (mo.n_experts + mo.n_shared) * nmat * d * mo.d_ff_expert \
                + d * mo.n_experts
            dense_layer = nmat * d * (mo.d_ff_dense or self.d_ff)
            ffn = mo.first_k_dense * dense_layer + (L - mo.first_k_dense) * ff_layer
        else:
            ffn = L * nmat * d * self.d_ff
        blocks = L * (attn + 2 * d) + (ffn if self.moe is not None else ffn)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return blocks + emb

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        all_experts = (self.n_layers - mo.first_k_dense) * \
            (mo.n_experts + mo.n_shared) * 3 * self.d_model * mo.d_ff_expert
        active = (self.n_layers - mo.first_k_dense) * \
            (mo.top_k + mo.n_shared) * 3 * self.d_model * mo.d_ff_expert
        return full - all_experts + active


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    aggregator: str = "attn"          # "attn" | "mean" | "sum" | "max"
    d_in: int = 1433
    n_classes: int = 7
    dropout: float = 0.0


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_dense: int = 0
    n_sparse: int = 26
    embed_dim: int = 16
    rows_per_field: int = 100_000     # synthetic vocab per sparse field
    mlp: Tuple[int, ...] = (400, 400, 400)
    interaction: str = "fm"           # "fm" | "cross" | "cin" | "dot"
    n_cross_layers: int = 0
    cin_layers: Tuple[int, ...] = ()
    tower_mlp: Tuple[int, ...] = ()   # two-tower
    n_candidates: int = 0             # retrieval-scoring candidate count


@dataclass(frozen=True)
class RetrievalConfig:
    """The paper's own system config (IVF early-exit dense retrieval)."""

    name: str
    n_docs: int = 8_800_000
    dim: int = 768
    n_clusters: int = 65_536
    n_probe: int = 80                 # N (A-kNN_95)
    k: int = 100
    tau: int = 10
    patience_delta: int = 7
    patience_phi: float = 95.0
    list_pad: int = 256               # padded scan tile (docs per probe step)
    storage_dtype: str = "float32"    # doc/centroid storage ("bfloat16"
                                      # halves the HBM-bound scan, §Perf)
    probe_width: int = 1              # clusters scanned per loop step
                                      # (amortises merges, §Perf iter 2)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                         # train|prefill|decode|long_decode|full_graph|
                                      # minibatch|batched_graphs|train_batch|serve|
                                      # retrieval|ivf_serve|ivf_build
    dims: Dict[str, int] = field(default_factory=dict)
    note: str = ""


LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "long_decode", {"seq_len": 524288, "global_batch": 1},
              note="bonus: full-attn decode is O(S)/step; seq-sharded KV (DESIGN §4)"),
)

GNN_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("full_graph_sm", "full_graph",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeSpec("minibatch_lg", "minibatch",
              {"n_nodes": 232_965, "n_edges": 114_615_892, "batch_nodes": 1024,
               "fanout0": 15, "fanout1": 10, "d_feat": 602}),
    ShapeSpec("ogb_products", "full_graph",
              {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100}),
    ShapeSpec("molecule", "batched_graphs",
              {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 64}),
)

RECSYS_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_batch", "train_batch", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

IVF_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("ivf_serve_1k", "ivf_serve", {"batch": 1024}),
    ShapeSpec("ivf_build", "ivf_build", {"sample": 1_048_576}),
)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # "lm" | "gnn" | "recsys" | "ivf"
    model: Any
    shapes: Tuple[ShapeSpec, ...]
    source: str = ""


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    if spec.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {spec.arch_id}")
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def shape_for(spec: ArchSpec, shape_name: str) -> ShapeSpec:
    for s in spec.shapes:
        if s.name == shape_name:
            return s
    raise KeyError(f"{spec.arch_id} has no shape {shape_name!r}; "
                   f"known: {[s.name for s in spec.shapes]}")


def reduced(spec: ArchSpec) -> ArchSpec:
    """A tiny same-family config for CPU smoke tests (DESIGN §4)."""
    m = spec.model
    if spec.family == "lm":
        mo = m.moe
        if mo is not None:
            # capacity_factor 8: drop-free at smoke scale so
            # prefill/decode-vs-forward consistency checks are exact
            mo = dataclasses.replace(mo, n_experts=min(mo.n_experts, 8),
                                     d_ff_expert=64, d_ff_dense=128,
                                     top_k=min(mo.top_k, 2),
                                     capacity_factor=8.0)
        mla = MLAConfig(q_lora_rank=24, kv_lora_rank=16, qk_nope_head_dim=8,
                        qk_rope_head_dim=8, v_head_dim=8) if m.attn_type == "mla" else None
        small = dataclasses.replace(
            m, n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=max(1, min(m.n_kv_heads, 4) if m.n_kv_heads < m.n_heads else 4),
            d_ff=128, vocab_size=512, d_head=16, moe=mo, mla=mla, attn_chunk=16)
        shapes = (ShapeSpec("smoke_train", "train", {"seq_len": 32, "global_batch": 4}),
                  ShapeSpec("smoke_decode", "decode", {"seq_len": 64, "global_batch": 2}))
        return ArchSpec(spec.arch_id + "-smoke", "lm", small, shapes)
    if spec.family == "gnn":
        small = dataclasses.replace(m, d_in=32, n_classes=5)
        shapes = (ShapeSpec("smoke_graph", "full_graph",
                            {"n_nodes": 64, "n_edges": 256, "d_feat": 32}),)
        return ArchSpec(spec.arch_id + "-smoke", "gnn", small, shapes)
    if spec.family == "recsys":
        small = dataclasses.replace(
            m, rows_per_field=128, embed_dim=8,
            mlp=tuple(min(x, 32) for x in m.mlp) or (32,),
            cin_layers=tuple(min(x, 16) for x in m.cin_layers),
            tower_mlp=tuple(min(x, 32) for x in m.tower_mlp),
            n_candidates=min(m.n_candidates, 256) if m.n_candidates else 0)
        shapes = (ShapeSpec("smoke_train", "train_batch", {"batch": 32}),
                  ShapeSpec("smoke_serve", "serve", {"batch": 8}))
        return ArchSpec(spec.arch_id + "-smoke", "recsys", small, shapes)
    if spec.family == "ivf":
        small = dataclasses.replace(m, n_docs=4096, dim=32, n_clusters=64,
                                    n_probe=16, k=10, tau=3, list_pad=64)
        shapes = (ShapeSpec("smoke_serve", "ivf_serve", {"batch": 8}),)
        return ArchSpec(spec.arch_id + "-smoke", "ivf", small, shapes)
    raise ValueError(spec.family)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        minicpm3_4b, qwen1_5_32b, starcoder2_3b, deepseek_moe_16b, dbrx_132b,
        gat_cora, deepfm, dcn_v2, two_tower_retrieval, xdeepfm, msmarco_ivf)
