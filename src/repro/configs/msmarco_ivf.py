"""The paper's own system: IVF early-exit dense retrieval on an
MS-MARCO-scale corpus (8.8M x 768, 65536 clusters, STAR operating point
N=80, k=100, tau=10, patience Delta=7 Phi=95).
"""
from repro.configs.base import (ArchSpec, IVF_SHAPES, RetrievalConfig,
                                register)

# paper-faithful defaults; the §Perf-optimised serving variant uses
# storage_dtype="bfloat16", probe_width=4 (see EXPERIMENTS.md §Perf)
MODEL = RetrievalConfig(name="msmarco-ivf", n_docs=8_800_000, dim=768,
                        n_clusters=65_536, n_probe=80, k=100, tau=10,
                        patience_delta=7, patience_phi=95.0, list_pad=256)

SPEC = register(ArchSpec("msmarco-ivf", "ivf", MODEL, IVF_SHAPES,
                         source="CIKM'24 Busolin et al."))
