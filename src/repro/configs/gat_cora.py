"""GAT (Cora) — 2-layer graph attention network. [arXiv:1710.10903; paper]"""
from repro.configs.base import ArchSpec, GNN_SHAPES, GNNConfig, register

MODEL = GNNConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                  aggregator="attn", d_in=1433, n_classes=7)

SPEC = register(ArchSpec("gat-cora", "gnn", MODEL, GNN_SHAPES,
                         source="arXiv:1710.10903"))
