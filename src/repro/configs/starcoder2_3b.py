"""StarCoder2-3B — dense LM, GQA kv=2, RoPE.

[arXiv:2402.19173; hf] 30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ArchSpec, LM_SHAPES, TransformerConfig, register

MODEL = TransformerConfig(
    name="starcoder2-3b", n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152, qkv_bias=True, rope_theta=100_000.0,
    mlp_type="gelu", tie_embeddings=True)

SPEC = register(ArchSpec("starcoder2-3b", "lm", MODEL, LM_SHAPES,
                         source="arXiv:2402.19173"))
