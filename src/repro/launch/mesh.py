"""Production mesh builders (functions — importing never touches jax
device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 2, model: int = 4):
    """Small mesh over host devices for tests (requires
    xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
