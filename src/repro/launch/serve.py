"""Retrieval serving driver: build an IVF index over a corpus, pick a
policy, stream a query log through the wave scheduler and report the
paper's effectiveness/efficiency metrics.

    PYTHONPATH=src python -m repro.launch.serve --policy patience \
        --n-docs 50000 --queries 1024
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import build_index, brute_force, metrics, policies, search
from repro.core.serving import WaveScheduler
from repro.data.synthetic import clustered_corpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="patience",
                    choices=["fixed", "patience"])
    ap.add_argument("--n-docs", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--n-probe", type=int, default=48)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--delta", type=int, default=5)
    ap.add_argument("--phi", type=float, default=95.0)
    ap.add_argument("--wave-size", type=int, default=128)
    ap.add_argument("--no-compact", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    c = clustered_corpus(n_docs=args.n_docs, dim=args.dim,
                         n_components=args.clusters,
                         n_queries=args.queries, seed=0)
    index = build_index(c.docs, args.clusters, list_pad=256, n_iters=6)
    print(f"index built: {index.n_clusters} clusters "
          f"({time.time() - t0:.1f}s)")

    _, exact = brute_force(jnp.asarray(c.docs), jnp.asarray(c.queries),
                           args.k)
    exact = np.asarray(exact)

    if args.policy == "fixed":
        pol = policies.fixed(args.n_probe, k=args.k)
        res = search(index, jnp.asarray(c.queries), pol)
        ids, probes = np.asarray(res.topk_ids), np.asarray(res.probes)
        print(metrics.summarize(ids, probes, exact, c.relevant))
        return

    ws = WaveScheduler(index, wave_size=args.wave_size, chunk=4,
                       k=args.k, n_probe=args.n_probe, delta=args.delta,
                       phi=args.phi)
    t1 = time.time()
    rep = ws.serve(c.queries, compact=not args.no_compact)
    wall = (time.time() - t1) * 1000
    ids = np.stack([rep.results[i] for i in range(args.queries)])
    probes = np.array([rep.probes[i] for i in range(args.queries)])
    summ = metrics.summarize(ids, probes, exact, c.relevant, wall)
    summ["occupancy"] = round(rep.occupancy, 3)
    summ["waves"] = rep.waves
    print({k: round(v, 4) if isinstance(v, float) else v
           for k, v in summ.items()})


if __name__ == "__main__":
    main()
