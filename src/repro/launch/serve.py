"""Retrieval serving driver: build an IVF index over a corpus, pick a
policy, stream a query log through the wave scheduler and report the
paper's effectiveness/efficiency metrics.

    PYTHONPATH=src python -m repro.launch.serve --policy patience \
        --n-docs 50000 --queries 1024

Live mutation (``repro.index``): ``--mutation-rate R`` injects R
document adds per wave (plus R//4 deletes of previously added docs)
*while the query stream is in flight*, through a ``LiveIndex`` +
``IndexRegistry`` pair; ``--merge-every M`` folds the delta buffer
into a fresh immutable index version every M waves.  The driver then
reports live-vs-static recall so regressions in the overlay path are
visible at the CLI.

Background re-clustering (``repro.index.rebuild``):
``--rebuild-every N`` requests a crash-safe centroid rebuild every N
waves; ``--rebuild-drift R`` instead arms a :class:`DriftTracker`
that requests one when added docs drift R× off the build-time
baseline.  Rebuild stages interleave with serving waves (throttled
under deadline pressure) and the swap is epoch-fenced: in-flight
lanes drain on the pinned version before the scheduler adopts the
re-clustered index.

Chaos mode (``repro.runtime.chaos``): ``--chaos`` runs the seeded
resilience drills — crash + WAL recovery over a mutation stream,
recall-vs-deadline curve under latency spikes, and shard-fault
retry/skip — and writes ``artifacts/BENCH_resilience.json``:

    PYTHONPATH=src python -m repro.launch.serve --chaos \
        --n-docs 4000 --queries 64 --clusters 32

``--deadline-ms`` (without ``--chaos``) serves the stream under a real
per-query latency budget through the degradation ladder.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.core import build_index, brute_force, metrics, policies, search
from repro.core.serving import WaveScheduler
from repro.data.synthetic import clustered_corpus
from repro.index import DeltaFull, IndexRegistry, LiveIndex, version_of


def _serve(ws, queries, *, compact, on_wave=None):
    t1 = time.time()
    rep = ws.serve(queries, compact=compact, on_wave=on_wave)
    wall = (time.time() - t1) * 1000
    n = queries.shape[0]
    ids = np.stack([rep.results[i] for i in range(n)])
    probes = np.array([rep.probes[i] for i in range(n)])
    return rep, ids, probes, wall


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="patience",
                    choices=["fixed", "patience"])
    ap.add_argument("--n-docs", type=int, default=50_000)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--clusters", type=int, default=256)
    ap.add_argument("--queries", type=int, default=1024)
    ap.add_argument("--n-probe", type=int, default=48)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--delta", type=int, default=5)
    ap.add_argument("--phi", type=float, default=95.0)
    ap.add_argument("--wave-size", type=int, default=128)
    ap.add_argument("--no-compact", action="store_true")
    ap.add_argument("--mutation-rate", type=int, default=0,
                    help="doc adds per wave (deletes at rate//4) "
                         "streamed against the live index")
    ap.add_argument("--merge-every", type=int, default=16,
                    help="fold the delta buffer into a new index "
                         "version every N waves")
    ap.add_argument("--delta-cap", type=int, default=4096,
                    help="delta buffer capacity (slots)")
    ap.add_argument("--rebuild-every", type=int, default=0,
                    help="request a background centroid rebuild every "
                         "N waves of the live stream (0 = off); stages "
                         "interleave with serving waves and the swap "
                         "is epoch-fenced")
    ap.add_argument("--rebuild-drift", type=float, default=0.0,
                    help="drift-ratio threshold that triggers a "
                         "rebuild (0 = off): mean nearest-centroid "
                         "distance of added docs vs the build-time "
                         "baseline")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-query latency budget; under pressure the "
                         "scheduler walks the degradation ladder "
                         "instead of blowing it")
    ap.add_argument("--chaos", action="store_true",
                    help="run the seeded resilience drills and write "
                         "artifacts/BENCH_resilience.json")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-crash-every", type=int, default=7,
                    help="inject a crash at every Nth mutation "
                         "boundary (0 = off)")
    ap.add_argument("--chaos-shard-fault-rate", type=float, default=0.3)
    ap.add_argument("--chaos-spike-rate", type=float, default=0.15)
    ap.add_argument("--chaos-deadlines", default="2,5,10,25",
                    help="comma-separated deadline_ms sweep")
    ap.add_argument("--chaos-out", default=None,
                    help="output JSON path (default "
                         "artifacts/BENCH_resilience.json)")
    args = ap.parse_args()

    t0 = time.time()
    c = clustered_corpus(n_docs=args.n_docs, dim=args.dim,
                         n_components=args.clusters,
                         n_queries=args.queries, seed=0)
    index = build_index(c.docs, args.clusters, list_pad=256, n_iters=6)
    print(f"index built: {index.n_clusters} clusters "
          f"({time.time() - t0:.1f}s)")

    _, exact = brute_force(jnp.asarray(c.docs), jnp.asarray(c.queries),
                           args.k)
    exact = np.asarray(exact)

    if args.chaos:
        from repro.runtime.chaos import ChaosConfig, run_chaos
        cfg = ChaosConfig(seed=args.chaos_seed,
                          crash_every=args.chaos_crash_every,
                          shard_fault_rate=args.chaos_shard_fault_rate,
                          spike_rate=args.chaos_spike_rate)
        deadlines = [float(x) for x in
                     args.chaos_deadlines.split(",") if x]
        with tempfile.TemporaryDirectory(prefix="chaos_") as workdir:
            payload = run_chaos(index, c.docs, c.queries, exact, cfg,
                                workdir, k=args.k,
                                n_probe=args.n_probe,
                                deadlines_ms=deadlines)
        out = args.chaos_out or os.path.join("artifacts",
                                             "BENCH_resilience.json")
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(json.dumps({"recovery": payload["recovery"],
                          "shard_faults": payload["shard_faults"]},
                         indent=2))
        print(f"wrote {out}")
        return

    if args.policy == "fixed":
        pol = policies.fixed(args.n_probe, k=args.k)
        res = search(index, jnp.asarray(c.queries), pol)
        ids, probes = np.asarray(res.topk_ids), np.asarray(res.probes)
        print(metrics.summarize(ids, probes, exact, c.relevant))
        return

    ws = WaveScheduler(index, wave_size=args.wave_size, chunk=4,
                       k=args.k, n_probe=args.n_probe, delta=args.delta,
                       phi=args.phi, deadline_ms=args.deadline_ms)
    rep, ids, probes, wall = _serve(ws, c.queries,
                                    compact=not args.no_compact)
    summ = metrics.summarize(ids, probes, exact, c.relevant, wall)
    summ["occupancy"] = round(rep.occupancy, 3)
    summ["waves"] = rep.waves
    if args.deadline_ms is not None:
        summ["degraded_fraction"] = round(rep.degraded_fraction, 4)
        summ["wave_cost_ms"] = round(rep.wave_cost_ms, 3)
    print({k: round(v, 4) if isinstance(v, float) else v
           for k, v in summ.items()})

    if args.mutation_rate <= 0:
        return

    # --- mixed query/mutation stream over the live index ------------------
    rebuild_on = args.rebuild_every > 0 or args.rebuild_drift > 0
    rebuilder = tracker = rb_tmp = None
    if rebuild_on:
        # a durable rebuild needs a WAL (catch-up across stages) and a
        # snapshot root (two-phase publish); both are scratch here
        from repro.checkpoint.manager import CheckpointManager
        from repro.index import DriftTracker, MutationWAL, Rebuilder
        rb_tmp = tempfile.TemporaryDirectory(prefix="serve_rebuild_")
        wal = MutationWAL(os.path.join(rb_tmp.name, "mutations.wal"),
                          group_commit_n=8, group_commit_ms=50.0)
        live = LiveIndex(index, delta_cap=args.delta_cap, wal=wal)
        mgr = CheckpointManager(os.path.join(rb_tmp.name, "snapshots"),
                                async_save=False)
    else:
        live = LiveIndex(index, delta_cap=args.delta_cap)
        mgr = None
    reg = IndexRegistry(version_of(live))
    if rebuild_on:
        reg.save(mgr)
        live.wal.note_durable(live.seq)

        def on_publish(new_live, report):
            nonlocal live
            live = new_live          # rebind the mutation stream
            if tracker is not None:
                tracker.rebase(new_live._centroids)

        rebuilder = Rebuilder(live, reg, mgr, on_publish=on_publish)
        if args.rebuild_drift > 0:
            tracker = DriftTracker(live._centroids, c.docs,
                                   threshold=args.rebuild_drift)
    ws_live = WaveScheduler(index, wave_size=args.wave_size, chunk=4,
                            k=args.k, n_probe=args.n_probe,
                            delta=args.delta, phi=args.phi, registry=reg,
                            deadline_ms=args.deadline_ms,
                            rebuilder=rebuilder)
    rng = np.random.default_rng(1)
    added: list[int] = []
    stats = {"adds": 0, "deletes": 0, "merges": 0}

    def mutate(wave: int) -> None:
        # corpus-like churn: noisy copies of existing docs, so added
        # vectors score on the same scale as the static corpus
        src = rng.integers(0, args.n_docs, args.mutation_rate)
        new = (c.docs[src]
               + rng.normal(scale=0.05, size=(args.mutation_rate,
                                              args.dim))
               ).astype(np.float32)
        try:
            added.extend(int(i) for i in live.add(new))
            stats["adds"] += args.mutation_rate
            if tracker is not None:
                tracker.observe(new)
        except DeltaFull:
            live.merge_delta()
            stats["merges"] += 1
        n_del = args.mutation_rate // 4
        if n_del and len(added) > n_del:
            doomed = [added.pop(rng.integers(len(added)))
                      for _ in range(n_del)]
            live.delete(doomed)
            stats["deletes"] += n_del
        if args.merge_every and wave % args.merge_every == 0 \
                and len(live.delta):
            live.merge_delta()
            stats["merges"] += 1
        reg.publish(version_of(live))
        if rebuilder is not None and not rebuilder.active:
            if args.rebuild_every and wave % args.rebuild_every == 0:
                rebuilder.request(f"every-{args.rebuild_every}")
            elif tracker is not None and tracker.triggered:
                rebuilder.request(f"drift>{args.rebuild_drift}")

    rep_l, ids_l, probes_l, wall_l = _serve(
        ws_live, c.queries, compact=not args.no_compact, on_wave=mutate)
    r_static = metrics.r_star_at_k(ids, exact)
    r_live = metrics.r_star_at_k(ids_l, exact)
    row = {"mode": "live", "mutation_rate": args.mutation_rate,
           "merge_every": args.merge_every, **stats,
           "versions": live.version, "swaps": reg.swaps,
           "delta_occupancy": round(live.delta.occupancy(), 3),
           "recall_static": round(r_static, 4),
           "recall_live": round(r_live, 4),
           "recall_gap": round(abs(r_static - r_live), 4),
           "latency_ms": round(wall_l, 1),
           "mean_probes": round(float(probes_l.mean()), 2)}
    if rebuilder is not None:
        row.update({"rebuilds": rebuilder.epochs_published,
                    "epoch": live.epoch,
                    "epoch_swaps": rep_l.epoch_swaps,
                    "drain_waves": rep_l.drain_waves,
                    "rebuild_ticks": rep_l.rebuild_ticks,
                    "rebuild_throttled": rep_l.rebuild_throttled})
        if tracker is not None:
            row["drift_ratio"] = round(tracker.ratio, 3)
    print(row)
    if rb_tmp is not None:
        live.wal.close()
        rb_tmp.cleanup()


if __name__ == "__main__":
    main()
