import os
# 512 placeholder devices for the production mesh. all-reduce-promotion
# is disabled: XLA:CPU's AllReducePromotion pass crashes (CreateBinary
# on a copy-rooted reduction) when differentiating through partial-auto
# shard_map (the MoE per-DP-shard dispatch); the pass is a CPU-only
# int16 promotion detail irrelevant to the TPU target.
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_disable_hlo_passes=all-reduce-promotion")

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and dump memory / cost / collective artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --mesh single --out artifacts/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The 512 placeholder host devices exist ONLY here (smoke tests and
benchmarks see 1 device). Compilation success per cell is the
deliverable; artifacts feed benchmarks/roofline.py.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.launch.hlo_analysis import parse_collectives


def _compile_once(fn, args, in_sh, out_sh, donate):
    jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    return compiled


def _cost_dict(compiled) -> Dict:
    ca = compiled.cost_analysis() or {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             *, skip_cost: bool = False) -> Dict:
    from repro.launch import cells as cells_lib
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec: Dict = {"arch": arch, "shape": shape,
                 "mesh": "multi" if multi_pod else "single",
                 "n_devices": mesh.size, "status": "ok"}
    from repro.distributed.context import activation_mesh
    try:
        with mesh, activation_mesh(mesh):
            cell = cells_lib.build_cell(arch, shape, mesh)
            compiled = _compile_once(cell.fn, cell.args,
                                     cell.in_shardings,
                                     cell.out_shardings,
                                     cell.donate_argnums)
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_gb": mem.argument_size_in_bytes / 1e9,
                "output_gb": mem.output_size_in_bytes / 1e9,
                "temp_gb": mem.temp_size_in_bytes / 1e9,
                "alias_gb": mem.alias_size_in_bytes / 1e9,
                "peak_gb": (mem.argument_size_in_bytes +
                            mem.output_size_in_bytes +
                            mem.temp_size_in_bytes -
                            mem.alias_size_in_bytes) / 1e9,
            }
            rec["note"] = cell.note
            rec["model_flops_global"] = cell.model_flops
            if not skip_cost:
                if cell.cost_variants is None:
                    rec["cost"] = _cost_dict(compiled)
                    rec["collectives"] = parse_collectives(
                        compiled.as_text()).to_dict()
                    rec["cost_method"] = "direct"
                else:
                    cv = cell.cost_variants
                    c1 = _compile_once(*cv["l1"], None, ())
                    c2 = _compile_once(*cv["l2"], None, ())
                    d1, d2 = _cost_dict(c1), _cost_dict(c2)
                    col1 = parse_collectives(c1.as_text())
                    col2 = parse_collectives(c2.as_text())
                    n = cv["n_scale"]
                    # extrapolation floor: never below the measured
                    # 2-layer program (partitioner choices can differ
                    # between L1 and L2, producing negative deltas)
                    rec["cost"] = {
                        k: max(d1[k] + n * (d2[k] - d1[k]), d2[k])
                        for k in d1}
                    per_kind = {}
                    kinds = set(col1.bytes_by_kind) | \
                        set(col2.bytes_by_kind)
                    for k in kinds:
                        b1 = col1.bytes_by_kind.get(k, 0)
                        b2 = col2.bytes_by_kind.get(k, 0)
                        per_kind[k] = max(b1 + n * (b2 - b1), b2, 0)
                    rec["collectives"] = {
                        "bytes_by_kind": per_kind,
                        "count_by_kind": col2.count_by_kind,
                        "total_bytes": sum(per_kind.values())}
                    rec["cost_method"] = "unrolled L1/L2 delta"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["compile_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{rec['mesh']}".replace("/", "_")
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-cost", action="store_true",
                    help="compile-only (no L1/L2 costing variants)")
    args = ap.parse_args()

    from repro.launch import cells as cells_lib
    todo = cells_lib.all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch, shape in todo:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out,
                           skip_cost=args.skip_cost)
            mark = "OK " if rec["status"] == "ok" else "FAIL"
            extra = "" if rec["status"] == "ok" else \
                " :: " + rec.get("error", "")[:160]
            peak = rec.get("memory", {}).get("peak_gb", float("nan"))
            print(f"[{mark}] {arch:22s} {shape:18s} "
                  f"{rec['mesh']:6s} peak={peak:8.2f}GB "
                  f"t={rec['compile_s']:6.1f}s{extra}", flush=True)
            n_fail += rec["status"] != "ok"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
