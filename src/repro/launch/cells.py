"""Dry-run cell construction: one Cell per (arch × shape × mesh).

A Cell carries the jit-able fn, abstract args (ShapeDtypeStructs — no
allocation), in/out shardings, and optional *cost variants*: unrolled
L=1 / L=2 programs whose compiled cost difference gives exact per-layer
FLOPs/bytes/collectives (XLA counts while bodies once; DESIGN §8).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cb
from repro.configs.base import get_arch, shape_for
from repro.distributed import sharding as shd
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf
from repro.optim.optimizers import adamw, warmup_cosine

SDS = jax.ShapeDtypeStruct


@dataclass
class Cell:
    name: str
    fn: Callable
    args: Tuple
    in_shardings: Any
    out_shardings: Any = None
    donate_argnums: Tuple[int, ...] = ()
    cost_variants: Optional[Dict] = None   # {"l1": (fn,args,in_sh), "l2":…,
                                           #  "n_scale": layers-1 multiplier}
    model_flops: float = 0.0               # global MODEL_FLOPS (6ND etc.)
    note: str = ""


def _dp(mesh: Mesh) -> Tuple[str, ...]:
    return shd.dp_axes(mesh)


def _named(mesh, tree):
    return shd.named(mesh, tree)


def _make_opt():
    return adamw(warmup_cosine(3e-4, 100, 10_000))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _microbatches(cfg, mesh, batch_size, seq) -> int:
    """Gradient-accumulation depth: keep per-shard microbatch around
    <=16k tokens (activation memory), power of two, divides B/dp."""
    dp_size = 1
    for a in _dp(mesh):
        dp_size *= mesh.shape[a]
    rows_per_shard = max(batch_size // dp_size, 1)
    m = 1
    while (rows_per_shard // m) * seq > 16384 and m < rows_per_shard \
            and (rows_per_shard // m) % 2 == 0:
        m *= 2
    return m


def _lm_train_pieces(cfg, mesh, batch_size, seq, *, unroll=False,
                     microbatches=1):
    dp = _dp(mesh)
    params_abs = tf.abstract_params(cfg)
    pspecs = shd.lm_param_specs(params_abs, mesh)
    opt = _make_opt()
    opt_abs = jax.eval_shape(opt.init, params_abs)
    opt_specs = {"mu": pspecs, "nu": pspecs}
    batch_abs = {"tokens": SDS((batch_size, seq), jnp.int32),
                 "labels": SDS((batch_size, seq), jnp.int32)}
    batch_specs = {"tokens": P(dp, None), "labels": P(dp, None)}
    m = microbatches
    loss_grad = jax.value_and_grad(
        functools.partial(tf.loss_fn, cfg, unroll=unroll), has_aux=True)

    def train_step(params, opt_state, step_idx, batch):
        if m == 1:
            (loss, _), grads = loss_grad(params, batch)
        else:
            # gradient accumulation over m microbatches (activation
            # memory /m; grads accumulate fp32 in param sharding)
            mbs = jax.tree.map(
                lambda a: a.reshape(m, a.shape[0] // m, *a.shape[1:]),
                batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                loss_sum, gacc = carry
                (loss, _), g = loss_grad(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (loss_sum + loss, gacc), None

            (loss, grads), _ = jax.lax.scan(
                mb_step, (jnp.zeros((), jnp.float32), g0), mbs)
            loss = loss / m
            grads = jax.tree.map(lambda g_: g_ / m, grads)
        new_p, new_s = opt.update(grads, opt_state, params, step_idx)
        return new_p, new_s, loss

    in_sh = (_named(mesh, pspecs), _named(mesh, opt_specs),
             NamedSharding(mesh, P()), _named(mesh, batch_specs))
    out_sh = (_named(mesh, pspecs), _named(mesh, opt_specs),
              NamedSharding(mesh, P()))
    args = (params_abs, opt_abs, SDS((), jnp.int32), batch_abs)
    return train_step, args, in_sh, out_sh


def _lm_train_cell(spec, shape, mesh) -> Cell:
    cfg = spec.model
    bs, seq = shape.dims["global_batch"], shape.dims["seq_len"]
    m = _microbatches(cfg, mesh, bs, seq)
    fn, args, in_sh, out_sh = _lm_train_pieces(cfg, mesh, bs, seq,
                                               microbatches=m)
    nd = cfg.moe.first_k_dense if cfg.moe else 0
    cfg1 = dataclasses.replace(cfg, n_layers=nd + 1)
    cfg2 = dataclasses.replace(cfg, n_layers=nd + 2)
    # cost variants run un-microbatched (same FLOPs, no inner while so
    # the L1/L2 delta stays exact) and layer-unrolled
    v1 = _lm_train_pieces(cfg1, mesh, bs, seq, unroll=True)
    v2 = _lm_train_pieces(cfg2, mesh, bs, seq, unroll=True)
    tokens = bs * seq
    return Cell(
        name=f"{spec.arch_id}:{shape.name}",
        fn=fn, args=args, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=(0, 1),
        cost_variants={"l1": v1[:3], "l2": v2[:3],
                       "n_scale": cfg.n_layers - nd - 1},
        model_flops=6.0 * cfg.active_param_count() * tokens,
        note=f"train_step = fwd+bwd+AdamW; remat/layer; "
             f"{m} microbatches")


def _lm_prefill_pieces(cfg, mesh, bs, seq, *, unroll=False):
    dp = _dp(mesh)
    params_abs = tf.abstract_params(cfg)
    pspecs = shd.lm_param_specs(params_abs, mesh)
    tokens_abs = SDS((bs, seq), jnp.int32)

    def fn(params, tokens):
        return tf.prefill(cfg, params, tokens, unroll=unroll)

    in_sh = (_named(mesh, pspecs), NamedSharding(mesh, P(dp, None)))
    return fn, (params_abs, tokens_abs), in_sh


def _lm_prefill_cell(spec, shape, mesh) -> Cell:
    cfg = spec.model
    bs, seq = shape.dims["global_batch"], shape.dims["seq_len"]
    fn, args, in_sh = _lm_prefill_pieces(cfg, mesh, bs, seq)
    nd = cfg.moe.first_k_dense if cfg.moe else 0
    cfg1 = dataclasses.replace(cfg, n_layers=nd + 1)
    cfg2 = dataclasses.replace(cfg, n_layers=nd + 2)
    return Cell(
        name=f"{spec.arch_id}:{shape.name}",
        fn=fn, args=args, in_shardings=in_sh,
        cost_variants={
            "l1": _lm_prefill_pieces(cfg1, mesh, bs, seq, unroll=True),
            "l2": _lm_prefill_pieces(cfg2, mesh, bs, seq, unroll=True),
            "n_scale": cfg.n_layers - nd - 1},
        model_flops=2.0 * cfg.active_param_count() * bs * seq,
        note="prefill: chunked-causal attention, returns KV cache")


def _lm_decode_pieces(cfg, mesh, bs, seq, *, long: bool, unroll=False):
    dp = _dp(mesh)
    params_abs = tf.abstract_params(cfg)
    pspecs = shd.lm_param_specs(params_abs, mesh)
    cache_abs = tf.abstract_cache(cfg, bs, seq)
    cache_specs = shd.lm_cache_specs(cache_abs, mesh, seq_sharded=long)
    tok_abs = SDS((bs, 1), jnp.int32)
    tok_spec = P(None, None) if bs == 1 else P(dp, None)

    def fn(params, cache, token, pos):
        return tf.decode_step(cfg, params, cache, token, pos,
                              unroll=unroll)

    in_sh = (_named(mesh, pspecs), _named(mesh, cache_specs),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
    out_sh = (None, _named(mesh, cache_specs))
    args = (params_abs, cache_abs, tok_abs, SDS((), jnp.int32))
    return fn, args, in_sh, out_sh


def _lm_decode_cell(spec, shape, mesh) -> Cell:
    cfg = spec.model
    bs, seq = shape.dims["global_batch"], shape.dims["seq_len"]
    long = shape.kind == "long_decode"
    fn, args, in_sh, out_sh = _lm_decode_pieces(cfg, mesh, bs, seq,
                                                long=long)
    nd = cfg.moe.first_k_dense if cfg.moe else 0
    cfg1 = dataclasses.replace(cfg, n_layers=nd + 1)
    cfg2 = dataclasses.replace(cfg, n_layers=nd + 2)
    v1 = _lm_decode_pieces(cfg1, mesh, bs, seq, long=long, unroll=True)
    v2 = _lm_decode_pieces(cfg2, mesh, bs, seq, long=long, unroll=True)
    return Cell(
        name=f"{spec.arch_id}:{shape.name}",
        fn=fn, args=args, in_shardings=in_sh, out_shardings=out_sh,
        donate_argnums=(1,),
        cost_variants={"l1": v1[:3], "l2": v2[:3],
                       "n_scale": cfg.n_layers - nd - 1},
        model_flops=2.0 * cfg.active_param_count() * bs,
        note=("long-context decode: KV cache sequence-sharded over all "
              "mesh axes" if long else
              f"decode: KV cache {cfg.kv_cache_dtype}, heads over model"))


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_train_step(cfg, opt):
    def step(params, opt_state, step_idx, graph):
        (loss, _), grads = jax.value_and_grad(
            functools.partial(gnn_lib.loss_fn, cfg), has_aux=True
        )(params, graph)
        new_p, new_s = opt.update(grads, opt_state, params, step_idx)
        return new_p, new_s, loss
    return step


def _gnn_cell(spec, shape, mesh) -> Cell:
    cfg0 = spec.model
    dims = shape.dims
    d_feat = dims.get("d_feat", cfg0.d_in)
    cfg = dataclasses.replace(cfg0, d_in=d_feat)
    all_axes = tuple(mesh.axis_names)
    params_abs = jax.eval_shape(
        functools.partial(gnn_lib.init_params, cfg), jax.random.PRNGKey(0))
    pspecs = shd.gnn_param_specs(params_abs)
    opt = _make_opt()
    opt_abs = jax.eval_shape(opt.init, params_abs)
    opt_specs = {"mu": pspecs, "nu": pspecs}

    if shape.kind == "minibatch":
        from repro.data.graph_sampler import block_shapes
        fanouts = (dims["fanout0"], dims["fanout1"])
        bn = dims["batch_nodes"]
        shapes = block_shapes(bn, fanouts)
        blocks_abs = [{"edge_src": SDS((e,), jnp.int32),
                       "edge_dst": SDS((e,), jnp.int32),
                       "edge_mask": SDS((e,), jnp.bool_)}
                      for (e, n, o) in shapes]
        n_outs = tuple(o for (_, _, o) in shapes)
        feats_abs = SDS((shapes[-1][1], d_feat), jnp.float32)
        labels_abs = SDS((bn,), jnp.int32)

        def step(params, opt_state, step_idx, feats, blocks, labels):
            (loss, _), grads = jax.value_and_grad(
                functools.partial(gnn_lib.loss_blocks, cfg,
                                  n_outs=n_outs), has_aux=True
            )(params, feats, blocks, labels)
            new_p, new_s = opt.update(grads, opt_state, params, step_idx)
            return new_p, new_s, loss

        bspec = [{k: P(all_axes) for k in b} for b in blocks_abs]
        in_sh = (_named(mesh, pspecs), _named(mesh, opt_specs),
                 NamedSharding(mesh, P()), NamedSharding(mesh, P()),
                 _named(mesh, bspec), NamedSharding(mesh, P()))
        args = (params_abs, opt_abs, SDS((), jnp.int32), feats_abs,
                blocks_abs, labels_abs)
        note = f"sampled minibatch, fanout {fanouts}"
    else:
        if shape.kind == "batched_graphs":
            bsz = dims["batch"]
            n = dims["n_nodes"] * bsz
            e = dims["n_edges"] * bsz
            note = f"disjoint union of {bsz} molecule graphs"
        else:
            n, e = dims["n_nodes"], dims["n_edges"]
            note = "full-graph training; edges sharded over all axes"
        e = ((e + 1023) // 1024) * 1024   # pad: inputs must shard evenly
        graph_abs = gnn_lib.Graph(
            feat=SDS((n, d_feat), jnp.float32),
            edge_src=SDS((e,), jnp.int32),
            edge_dst=SDS((e,), jnp.int32),
            label=SDS((n,), jnp.int32), edge_mask=None)
        gspecs = gnn_lib.Graph(feat=P(), edge_src=P(all_axes),
                               edge_dst=P(all_axes), label=P(),
                               edge_mask=None)
        step = _gnn_train_step(cfg, opt)
        in_sh = (_named(mesh, pspecs), _named(mesh, opt_specs),
                 NamedSharding(mesh, P()), _named(mesh, gspecs))
        args = (params_abs, opt_abs, SDS((), jnp.int32), graph_abs)

    # GAT flops ~ 3*(edges*heads*d_hidden)*2 per layer fwd, x3 for bwd
    e_total = dims.get("n_edges", 0) * dims.get("batch", 1)
    mf = 6.0 * 3 * e_total * cfg.n_heads * cfg.d_hidden
    return Cell(name=f"{spec.arch_id}:{shape.name}", fn=step, args=args,
                in_shardings=in_sh, donate_argnums=(0, 1),
                model_flops=mf, note=note)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_cell(spec, shape, mesh) -> Cell:
    cfg = spec.model
    dp = _dp(mesh)
    dims = shape.dims
    params_abs = jax.eval_shape(
        functools.partial(rec_lib.init_params, cfg), jax.random.PRNGKey(0))
    pspecs = shd.recsys_param_specs(params_abs, mesh)

    if shape.kind == "retrieval":
        nc = dims["n_candidates"]
        all_axes = tuple(mesh.axis_names)
        if cfg.tower_mlp:
            # two-tower: dot the query against the candidate store
            d = cfg.tower_mlp[-1]
            q_abs = SDS((max(dims["batch"], 1), d), jnp.float32)
            cand_abs = SDS((nc, d), jnp.float32)

            def fn(q, cand):
                return rec_lib.score_candidates(q, cand, k=100)

            cand_spec = shd.fit(P(all_axes, None), (nc, d), mesh)
            if cand_spec == P(None, None):   # 1e6 % 256 != 0: fall back
                cand_spec = shd.fit(P(_dp(mesh), None), (nc, d), mesh)
            in_sh = (NamedSharding(mesh, P(None, None)),
                     NamedSharding(mesh, cand_spec))
            return Cell(name=f"{spec.arch_id}:{shape.name}", fn=fn,
                        args=(q_abs, cand_abs), in_shardings=in_sh,
                        model_flops=2.0 * nc * d,
                        note="brute-force candidate scoring (baseline); "
                             "see the :retrieval_cand_ivf cell for the "
                             "paper's early-exit path")
        # CTR models: pointwise-score 1M candidate items for one user
        # context, return the top-100
        cand_batch = {"dense": SDS((nc, max(cfg.n_dense, 0)),
                                   jnp.float32),
                      "sparse": SDS((nc, cfg.n_sparse), jnp.int32),
                      "label": SDS((nc,), jnp.float32)}
        cspec = shd.fit(P(_dp(mesh), None), (nc, cfg.n_sparse), mesh)
        cand_specs = {"dense": cspec, "sparse": cspec,
                      "label": P(cspec[0])}

        def fn(params, batch):
            logits = rec_lib.serve_logits(cfg, params, batch)
            return jax.lax.top_k(logits, 100)

        in_sh = (_named(mesh, pspecs), _named(mesh, cand_specs))
        return Cell(name=f"{spec.arch_id}:{shape.name}", fn=fn,
                    args=(params_abs, cand_batch), in_shardings=in_sh,
                    model_flops=_recsys_flops(cfg, nc),
                    note="CTR pointwise scoring of 1M candidates + "
                         "top-100")

    bsz = dims["batch"]
    batch_abs = {"dense": SDS((bsz, max(cfg.n_dense, 0)), jnp.float32),
                 "sparse": SDS((bsz, cfg.n_sparse), jnp.int32),
                 "label": SDS((bsz,), jnp.float32)}
    batch_specs = {"dense": P(dp, None), "sparse": P(dp, None),
                   "label": P(dp)}

    if shape.kind == "train_batch":
        opt = _make_opt()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_specs = {"mu": pspecs, "nu": pspecs}

        def step(params, opt_state, step_idx, batch):
            (loss, _), grads = jax.value_and_grad(
                functools.partial(rec_lib.loss_fn, cfg), has_aux=True
            )(params, batch)
            new_p, new_s = opt.update(grads, opt_state, params, step_idx)
            return new_p, new_s, loss

        in_sh = (_named(mesh, pspecs), _named(mesh, opt_specs),
                 NamedSharding(mesh, P()), _named(mesh, batch_specs))
        args = (params_abs, opt_abs, SDS((), jnp.int32), batch_abs)
        return Cell(name=f"{spec.arch_id}:{shape.name}", fn=step,
                    args=args, in_shardings=in_sh, donate_argnums=(0, 1),
                    model_flops=_recsys_flops(cfg, bsz) * 3,
                    note="train_step; embedding tables row-sharded over "
                         "model")

    def serve(params, batch):
        return rec_lib.serve_logits(cfg, params, batch)

    in_sh = (_named(mesh, pspecs), _named(mesh, batch_specs))
    return Cell(name=f"{spec.arch_id}:{shape.name}", fn=serve,
                args=(params_abs, batch_abs), in_shardings=in_sh,
                model_flops=_recsys_flops(cfg, bsz),
                note=f"pointwise scoring batch={bsz}")


def _recsys_flops(cfg, bsz: int) -> float:
    d_in = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    f = 0.0
    dims = (d_in,) + cfg.mlp + ((1,) if cfg.mlp else ())
    for a, b in zip(dims[:-1], dims[1:]):
        f += 2.0 * a * b
    for i, hk in enumerate(cfg.cin_layers):
        prev = cfg.n_sparse if i == 0 else cfg.cin_layers[i - 1]
        f += 2.0 * hk * prev * cfg.n_sparse * cfg.embed_dim
    if cfg.n_cross_layers:
        f += cfg.n_cross_layers * 2.0 * d_in * d_in
    if cfg.tower_mlp:
        dt = (cfg.n_sparse // 2) * cfg.embed_dim
        dims = (dt,) + cfg.tower_mlp
        for a, b in zip(dims[:-1], dims[1:]):
            f += 2.0 * 2.0 * a * b
    return f * bsz


# ---------------------------------------------------------------------------
# IVF (paper) cells
# ---------------------------------------------------------------------------


def _ivf_cell(spec, shape, mesh, *, arch_override=None) -> Cell:
    from repro.core import distributed_ivf as divf
    cfg = arch_override or spec.model
    dp = _dp(mesh)
    model_size = mesh.shape["model"]
    if shape.kind == "ivf_build":
        from repro.core.kmeans import sharded_assign_step
        n = shape.dims["sample"]
        x_abs = SDS((n, cfg.dim), jnp.float32)
        c_abs = SDS((cfg.n_clusters, cfg.dim), jnp.float32)
        fn = sharded_assign_step(mesh, "data")
        in_sh = (NamedSharding(mesh, P("data", None)),
                 NamedSharding(mesh, P()))
        return Cell(name=f"{spec.arch_id}:{shape.name}", fn=fn,
                    args=(x_abs, c_abs), in_shardings=in_sh,
                    model_flops=2.0 * n * cfg.n_clusters * cfg.dim,
                    note="one distributed Lloyd step (IVF build)")

    b = shape.dims["batch"]
    storage = getattr(cfg, "storage_dtype", "float32")
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "int8": jnp.int8}[storage]
    sh_abs = divf.abstract_sharded(
        cfg.n_docs, cfg.dim, cfg.n_clusters, cfg.list_pad, model_size,
        dtype=dt)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if b % dp_size:
        dp = ()                              # tiny batch: replicate
    q_abs = SDS((b, cfg.dim), jnp.float32)
    steps = int(np.ceil(cfg.n_probe /
                        (model_size * getattr(cfg, "probe_width", 1))))

    def build(unroll):
        return divf.make_distributed_search(
            mesh, n_probe=cfg.n_probe, k=cfg.k,
            patience_delta=cfg.patience_delta,
            patience_phi=cfg.patience_phi, list_pad=cfg.list_pad,
            dp_axes=dp, unroll_steps=unroll,
            probe_width=getattr(cfg, "probe_width", 1),
            int8_docs=storage == "int8")

    in_sh = (NamedSharding(mesh, P("model", None, None)),
             NamedSharding(mesh, P("model", None, None)),
             NamedSharding(mesh, P("model", None)),
             NamedSharding(mesh, P("model", None)),
             NamedSharding(mesh, P("model", None)),
             NamedSharding(mesh, P(dp, None)))
    args = [sh_abs.centroids, sh_abs.docs, sh_abs.doc_ids, sh_abs.offsets,
            sh_abs.sizes, q_abs]
    if storage == "int8":
        in_sh = in_sh + (NamedSharding(mesh, P("model", None)),)
        args.append(sh_abs.doc_scales)
    args = tuple(args)
    # adaptive (real) program + unrolled 1/2-step costing variants.
    # MODEL_FLOPS: centroid ranking happens once; the tile scan runs
    # `steps` times across all model shards.
    w_ = getattr(cfg, "probe_width", 1)
    scan_flops = 2.0 * b * cfg.list_pad * cfg.dim * model_size * w_
    rank_flops = 2.0 * b * cfg.n_clusters * cfg.dim
    return Cell(
        name=f"{spec.arch_id}:{shape.name}",
        fn=build(None), args=args, in_shardings=in_sh,
        cost_variants={"l1": (build(1), args, in_sh),
                       "l2": (build(2), args, in_sh),
                       "n_scale": steps - 1},
        model_flops=scan_flops * steps + rank_flops,
        note=f"adaptive patience search, {model_size} clusters/step, "
             f"<= {steps} steps")


def _retrieval_ivf_cell(spec, shape, mesh) -> Cell:
    """The paper's technique serving the two-tower candidate store."""
    cfg = spec.model
    rc = cb.RetrievalConfig(
        name="two-tower-ivf", n_docs=shape.dims["n_candidates"],
        dim=cfg.tower_mlp[-1], n_clusters=4096, n_probe=64, k=100,
        tau=10, patience_delta=7, list_pad=512)
    cell = _ivf_cell(spec, cb.ShapeSpec("retrieval_cand_ivf", "ivf_serve",
                                        {"batch": max(shape.dims["batch"],
                                                      1)}),
                     mesh, arch_override=rc)
    cell.note = "paper technique on the 1M-candidate store: " + cell.note
    return cell


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> Cell:
    spec = get_arch(arch_id)
    if shape_name == "retrieval_cand_ivf":
        return _retrieval_ivf_cell(spec, shape_for(spec, "retrieval_cand"),
                                   mesh)
    shape = shape_for(spec, shape_name)
    if spec.family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(spec, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(spec, shape, mesh)
        return _lm_decode_cell(spec, shape, mesh)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, mesh)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape, mesh)
    if spec.family == "ivf":
        return _ivf_cell(spec, shape, mesh)
    raise ValueError(spec.family)


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """The 40 assigned cells + the paper's own cells + the IVF-backed
    retrieval variant."""
    out = []
    for arch in cb.list_archs():
        spec = get_arch(arch)
        for s in spec.shapes:
            out.append((arch, s.name))
        if spec.family == "recsys" and spec.model.n_candidates:
            out.append((arch, "retrieval_cand_ivf"))
    return tuple(out)
