"""Post-SPMD HLO parsing: per-device collective bytes + roofline terms.

``compiled.as_text()`` is the partitioned per-device program, so every
shape is a per-device (local) shape. We sum the *result* bytes of every
collective op — the per-device ICI payload proxy. NOTE (methodology):
XLA's HloCostAnalysis counts while-loop bodies ONCE regardless of trip
count, so scanned-layer programs are costed via the unrolled L1/L2
delta trick in launch/dryrun.py, never from the scanned program.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches `bf16[128,1024]{1,0}` shape atoms
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def to_dict(self) -> Dict:
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            # result dtype[shape] ... = kind(...); also fused-start forms
            if re.search(rf"=\s*(\(|\w+\[)[^=]*\b{kind}(-start)?\(",
                         line):
                lhs = line.split("=", 1)[0] + "=" + \
                    line.split("=", 1)[1].split(f"{kind}", 1)[0]
                shapes = _SHAPE_RE.findall(lhs)
                b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
                stats.bytes_by_kind[kind] = \
                    stats.bytes_by_kind.get(kind, 0) + b
                stats.count_by_kind[kind] = \
                    stats.count_by_kind.get(kind, 0) + 1
                break
    return stats


# --- hardware constants (TPU v5e target) --------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per chip, one direction)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float
                   ) -> Dict[str, float]:
    """All inputs are per-device quantities; outputs in seconds."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1.0)
    return terms
