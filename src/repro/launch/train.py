"""Training driver (CPU-scale configs run for real; production configs
lower the same code on the dry-run mesh).

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch, reduced
from repro.data import pipeline as pipe
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf
from repro.optim.optimizers import adamw, warmup_cosine
from repro.runtime.fault import FaultTolerantTrainer


def make_lm_step(cfg, opt):
    @jax.jit
    def step(state, batch):
        params, opt_state, step_idx = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, _), grads = jax.value_and_grad(
            functools.partial(tf.loss_fn, cfg), has_aux=True
        )(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, step_idx)
        return (params, opt_state, step_idx + 1), loss
    return step


def make_recsys_step(cfg, opt):
    @jax.jit
    def step(state, batch):
        params, opt_state, step_idx = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, _), grads = jax.value_and_grad(
            functools.partial(rec_lib.loss_fn, cfg), has_aux=True
        )(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, step_idx)
        return (params, opt_state, step_idx + 1), loss
    return step


def build_trainer(arch: str, *, smoke: bool, ckpt_dir: str, seed: int = 0,
                  ckpt_every: int = 10, batch: int = 8, seq: int = 64
                  ) -> FaultTolerantTrainer:
    spec = get_arch(arch)
    if smoke:
        spec = reduced(spec)
    cfg = spec.model
    opt = adamw(warmup_cosine(1e-3, 20, 2000))
    key = jax.random.PRNGKey(seed)
    if spec.family == "lm":
        params = tf.init_params(cfg, key)
        step_fn = make_lm_step(cfg, opt)
        batcher = pipe.lm_batcher(cfg.vocab_size, batch, seq, seed)
    elif spec.family == "recsys":
        params = rec_lib.init_params(cfg, key)
        step_fn = make_recsys_step(cfg, opt)
        batcher = pipe.recsys_batcher(cfg.n_dense, cfg.n_sparse,
                                      cfg.rows_per_field, batch, seed)
    else:
        raise ValueError(f"train.py drives lm/recsys; got {spec.family}")
    state = (params, opt.init(params), jnp.zeros((), jnp.int32))
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    return FaultTolerantTrainer(step_fn, state, batcher, ckpt,
                                ckpt_every=ckpt_every)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one failure at this step (chaos drill)")
    args = ap.parse_args()
    trainer = build_trainer(args.arch, smoke=args.smoke,
                            ckpt_dir=args.ckpt_dir)
    fail = {args.fail_at: 1} if args.fail_at is not None else None
    rep = trainer.run(args.steps, fail_at=fail)
    print(f"steps={rep.steps_run} restarts={rep.restarts} "
          f"first_loss={rep.losses[0]:.4f} last_loss={rep.losses[-1]:.4f} "
          f"wall={rep.wall_s:.1f}s")


if __name__ == "__main__":
    main()
