"""Distributed-optimization helpers: bucketed gradient psum (overlap),
int8 error-feedback gradient compression, ring all-gather via ppermute.
"""
from __future__ import annotations

import functools
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


# --- int8 error-feedback gradient compression --------------------------------


def compress_int8(g: jnp.ndarray, err: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(g + carried error) -> (int8 payload, scale, new error).

    1-bit/8-bit SGD style error feedback: quantization residual is
    carried to the next step, preserving convergence (tested in
    tests/test_optim.py)."""
    x = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, err: jnp.ndarray, axis: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """all-reduce a gradient in int8 payload (8x ICI bytes saved).

    Payload on the wire is the int8 tensor + one f32 scale per shard;
    the reduction averages dequantized values (scales differ per shard).
    """
    q, scale, new_err = compress_int8(g, err)
    # wire format: int8 tensor (psum of widened int32 is the TPU
    # reduction; bytes on the ICI are the int8 payload)
    summed = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                          axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return (summed / n).astype(g.dtype), new_err


# --- bucketed gradient reduction (backward overlap) ---------------------------


def bucketed_psum(grads: Pytree, axis: str, bucket_bytes: int = 1 << 25
                  ) -> Pytree:
    """psum grads in size-bounded buckets. Under XLA latency-hiding
    scheduling, distinct collectives overlap the backward computation
    (one giant fused all-reduce cannot start until the last grad is
    ready)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out: List[jnp.ndarray] = []
    bucket: List[jnp.ndarray] = []
    size = 0

    def flush():
        nonlocal bucket, size
        if not bucket:
            return
        reduced = jax.lax.psum(tuple(bucket), axis)
        out.extend(reduced)
        bucket, size = [], 0

    for leaf in leaves:
        nbytes = leaf.size * leaf.dtype.itemsize
        if size + nbytes > bucket_bytes and bucket:
            flush()
        bucket.append(leaf)
        size += nbytes
    flush()
    return jax.tree_util.tree_unflatten(treedef, out)


# --- ring all-gather ----------------------------------------------------------


def ring_all_gather(x: jnp.ndarray, axis: str, axis_size: int
                    ) -> jnp.ndarray:
    """All-gather as axis_size-1 ppermute hops — each hop overlaps with
    consumer compute (the manual overlap schedule; XLA's all-gather is
    the monolithic alternative)."""
    chunks = [x]
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    cur = x
    for _ in range(axis_size - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        chunks.append(cur)
    # chunk j holds the shard of device (i - j) mod S; re-order by index
    idx = jax.lax.axis_index(axis)
    stacked = jnp.stack(chunks)                  # (S, ...) rotated
    order = (idx - jnp.arange(axis_size)) % axis_size
    inv = jnp.argsort(order)
    return jnp.take(stacked, inv, axis=0)
