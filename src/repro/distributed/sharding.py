"""Parameter / activation PartitionSpec rules per model family.

Mesh axes (launch/mesh.py): optional "pod" (cross-pod DP), "data"
(FSDP), "model" (TP/EP). LM params are TP-sharded on head/ff/vocab dims
over `model` and FSDP-sharded on the complementary dim over `data`
(ZeRO-3-alike — optimizer moments inherit the same specs). Dims that do
not divide evenly are padded by the SPMD partitioner (DESIGN §4:
qwen 40 heads @ TP16, etc.).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All data-parallel axes present in the mesh ("pod" included)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def fit(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on dims the shape does not divide evenly —
    program *inputs* must shard exactly (XLA pads only intermediates).
    E.g. minicpm's vocab 73448 is not divisible by model=16, so its
    embedding falls back to replicated-on-vocab."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        out.append(e if e is not None and dim % _axis_size(mesh, e) == 0
                   else None)
    return P(*out)


def fit_tree(specs, abstract_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, leaf: fit(s, leaf.shape, mesh), specs, abstract_tree,
        is_leaf=lambda x: isinstance(x, P))


# --- LM ---------------------------------------------------------------------

_LM_RULES = [
    # (path regex, spec builder given leaf ndim)
    (r"\['embed'\]$",                lambda n: P("model", "data")),
    (r"\['out'\]\['w'\]$",           lambda n: P("data", "model")),
    (r"\['out'\]\['b'\]$",           lambda n: P("model")),
    (r"\['attn'\]\['w[qkv]'\]\['w'\]$", lambda n: P("data", "model")),
    (r"\['attn'\]\['w[qkv]'\]\['b'\]$", lambda n: P("model")),
    (r"\['attn'\]\['wo'\]\['w'\]$",  lambda n: P("model", "data")),
    (r"\['attn'\]\['wo'\]\['b'\]$",  lambda n: P(None)),
    # MLA
    (r"\['wq_a'\]\['w'\]$",          lambda n: P("data", None)),
    (r"\['wq_b'\]\['w'\]$",          lambda n: P(None, "model")),
    (r"\['wkv_a'\]\['w'\]$",         lambda n: P("data", None)),
    (r"\['wk_b'\]\['w'\]$",          lambda n: P(None, "model")),
    (r"\['wv_b'\]\['w'\]$",          lambda n: P(None, "model")),
    # dense MLP
    (r"\['mlp'\]\['w[ig]'\]\['w'\]$", lambda n: P("data", "model")),
    (r"\['mlp'\]\['wo'\]\['w'\]$",   lambda n: P("model", "data")),
    # MoE
    (r"\['moe'\]\['router'\]\['w'\]$", lambda n: P(None, None)),
    # expert weights: EP on E + FSDP on d/ff; the MoE shard_map body
    # all-gathers them on use (ZeRO-3) — see models/moe.py
    (r"\['moe'\]\['w[ig]'\]$",       lambda n: P("model", "data", None)),
    (r"\['moe'\]\['wo'\]$",          lambda n: P("model", "data", None)),
    (r"\['moe'\]\['shared'\]\['w[ig]'\]\['w'\]$",
     lambda n: P(None, "model")),
    (r"\['moe'\]\['shared'\]\['wo'\]\['w'\]$",
     lambda n: P("model", None)),
]


def lm_param_specs(abstract_params: Pytree, mesh: Optional[Mesh] = None
                   ) -> Pytree:
    def spec_for(keypath, leaf):
        ks = jax.tree_util.keystr(keypath)
        stacked = "['layers']" in ks
        for pat, mk in _LM_RULES:
            if re.search(pat, ks):
                s = mk(leaf.ndim)
                if stacked:
                    s = P(None, *s)   # leading scan-layer dim
                if mesh is not None:
                    s = fit(s, leaf.shape, mesh)
                return s
        return P()                    # norms, small leftovers: replicate

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def lm_cache_specs(cache, mesh: Mesh, *, seq_sharded: bool) -> Pytree:
    """KV caches (L, B, S, KV, hd): batch over DP; KV heads over model
    when they divide evenly (deepseek kv=16 @ TP16), else the sequence
    dim carries the model sharding (qwen kv=40, dbrx kv=8, starcoder2
    kv=2 — S is always a power of two). long-context (B=1) shards S
    over every axis."""
    dp = dp_axes(mesh)
    ms = mesh.shape["model"]
    all_axes = tuple(mesh.axis_names)

    def spec_for(leaf):
        if leaf.ndim == 5:      # gqa k/v (L,B,S,KV,hd)
            b, kv = leaf.shape[1], leaf.shape[3]
            if b == 1:
                return fit(P(None, None, all_axes, None, None),
                           leaf.shape, mesh)
            if kv % ms == 0:
                return fit(P(None, dp, None, "model", None),
                           leaf.shape, mesh)
            return fit(P(None, dp, "model", None, None), leaf.shape, mesh)
        if leaf.ndim == 4:      # int8 scales (L,B,S,KV)
            b, kv = leaf.shape[1], leaf.shape[3]
            if b == 1:
                return fit(P(None, None, all_axes, None), leaf.shape,
                           mesh)
            if kv % ms == 0:
                return fit(P(None, dp, None, "model"), leaf.shape, mesh)
            return fit(P(None, dp, "model", None), leaf.shape, mesh)
        if leaf.ndim == 3:      # mla (L,B,S,r)
            b = leaf.shape[1]
            if b == 1:
                return fit(P(None, None, all_axes, None), leaf.shape,
                           mesh)
            return fit(P(None, dp, "model", None), leaf.shape, mesh)
        return P()

    return jax.tree.map(spec_for, cache)


# --- GNN ---------------------------------------------------------------------

def gnn_param_specs(abstract_params: Pytree) -> Pytree:
    return jax.tree.map(lambda _: P(), abstract_params)


def gnn_input_specs(mesh: Mesh) -> Any:
    """Edges sharded over every mesh axis; node arrays replicated."""
    all_axes = tuple(mesh.axis_names)
    from repro.models.gnn import Graph
    return Graph(feat=P(), edge_src=P(all_axes), edge_dst=P(all_axes),
                 label=P(), edge_mask=None)


# --- RecSys -------------------------------------------------------------------

def recsys_param_specs(abstract_params: Pytree,
                       mesh: Optional[Mesh] = None) -> Pytree:
    def spec_for(keypath, leaf):
        ks = jax.tree_util.keystr(keypath)
        s = P()
        if re.search(r"\['(table|linear_table)'\]$", ks):
            s = P("model", None)         # row-sharded embedding tables
        elif re.search(r"\['l\d+'\]\['w'\]$", ks) and leaf.ndim == 2 \
                and leaf.shape[0] >= 512:
            s = P("data", "model")       # big tower/mlp matrices
        return fit(s, leaf.shape, mesh) if mesh is not None else s

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def named(mesh: Mesh, specs: Pytree) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
