"""Model-parallel EmbeddingBag via shard_map (DESIGN §5).

Tables are row-sharded over `model`; every device resolves the ids that
fall in its row range and a psum combines — the table is never
all-gathered (the failure mode of naive pjit gathers on 10^8-row
tables). Ids arrive replicated across `model` and sharded over the DP
axes.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def make_sharded_lookup(mesh: Mesh, rows_total: int, *,
                        model_axis: str = "model",
                        dp_axes: Tuple[str, ...] = ("data",)):
    """Returns lookup(table, flat_ids) -> (B, F, D) embeddings.

    table: (rows_total, D) sharded P(model, None)
    flat_ids: (B, F) combined-table ids, sharded P(dp, None)
    """
    n_shards = 1
    for a in model_axis if isinstance(model_axis, tuple) else (model_axis,):
        n_shards *= mesh.shape[a]
    rows_local = (rows_total + n_shards - 1) // n_shards

    def local(table_shard: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
        i = jax.lax.axis_index(model_axis)
        r0 = i * rows_local
        loc = ids - r0
        ok = (loc >= 0) & (loc < table_shard.shape[0])
        emb = jnp.take(table_shard, jnp.clip(loc, 0, table_shard.shape[0]
                                             - 1), axis=0)
        emb = emb * ok[..., None].astype(emb.dtype)
        return jax.lax.psum(emb, model_axis)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(model_axis, None), P(dp_axes, None)),
        out_specs=P(dp_axes, None, None))


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  offsets: jnp.ndarray, *, combine: str = "sum"
                  ) -> jnp.ndarray:
    """Single-host EmbeddingBag oracle: ragged multi-hot bags.

    ids: (nnz,) row ids; offsets: (B+1,) bag boundaries -> (B, D).
    (The taxonomy-mandated take + segment_sum construction.)
    """
    nnz = ids.shape[0]
    b = offsets.shape[0] - 1
    seg = jnp.searchsorted(offsets[1:], jnp.arange(nnz), side="right")
    emb = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(emb, seg, num_segments=b)
    if combine == "mean":
        cnt = jax.ops.segment_sum(jnp.ones((nnz,), table.dtype), seg,
                                  num_segments=b)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out
