"""Activation-sharding context: the launcher announces the mesh layout;
model code places with_sharding_constraint on activations only when a
mesh is active (unit tests on 1 device see plain jnp).
"""
from __future__ import annotations

import contextlib
import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


@contextlib.contextmanager
def activation_mesh(mesh):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def current_mesh():
    return getattr(_STATE, "mesh", None)


def dp_axes() -> Optional[Tuple[str, ...]]:
    m = current_mesh()
    if m is None:
        return None
    return tuple(a for a in m.axis_names if a in ("pod", "data")) or None


def model_axis() -> Optional[str]:
    m = current_mesh()
    if m is None or "model" not in m.axis_names:
        return None
    return "model"


def model_size() -> int:
    m = current_mesh()
    return m.shape["model"] if m is not None and "model" in m.axis_names \
        else 1


def act(x, spec_template: Tuple, *, bf16_cotangent: bool = False
        ) -> "jax.Array":
    """Constrain activation sharding. Template entries:
    'dp' -> data axes, 'model' -> model axis, None -> unsharded.
    A 'dp' on a size-1 dim degrades to None (long-context decode B=1).
    No-op when no mesh is active.

    bf16_cotangent: cast the backward cotangent to bf16 before it
    crosses this (resharding) boundary — f32 cotangent all-gathers of
    the sequence-parallel residual otherwise dominate the collective
    roofline term (§Perf, qwen train hillclimb)."""
    m = current_mesh()
    if m is None:
        return x
    resolved = []
    for i, e in enumerate(spec_template):
        if e == "dp":
            axes = dp_axes()
            resolved.append(axes if axes and x.shape[i] > 1 else None)
        elif e == "model":
            resolved.append(model_axis())
        else:
            resolved.append(None)
    spec = P(*resolved)
    if not bf16_cotangent:
        return jax.lax.with_sharding_constraint(x, spec)
    return _act_bf16_ct(x, spec)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _act_bf16_ct(x, spec):
    return jax.lax.with_sharding_constraint(x, spec)


def _act_bf16_ct_fwd(x, spec):
    return jax.lax.with_sharding_constraint(x, spec), None


def _act_bf16_ct_bwd(spec, _, ct):
    ct = ct.astype(jnp.bfloat16)
    ct = jax.lax.with_sharding_constraint(ct, spec)
    return (ct,)


_act_bf16_ct.defvjp(_act_bf16_ct_fwd, _act_bf16_ct_bwd)
