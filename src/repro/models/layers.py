"""Shared neural building blocks (pure-jnp, pjit-friendly)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Params = Dict[str, jnp.ndarray]


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: Optional[float] = None) -> Params:
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p: Params, x: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def mlp_init(key, d: int, d_ff: int, mlp_type: str) -> Params:
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {"wi": dense_init(ks[0], d, d_ff),
                "wg": dense_init(ks[1], d, d_ff),
                "wo": dense_init(ks[2], d_ff, d, scale=1.0 / np.sqrt(d_ff))}
    return {"wi": dense_init(ks[0], d, d_ff),
            "wo": dense_init(ks[2], d_ff, d, scale=1.0 / np.sqrt(d_ff))}


def mlp(p: Params, x: jnp.ndarray, mlp_type: str,
        dtype=jnp.bfloat16) -> jnp.ndarray:
    if mlp_type == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x, dtype)) * dense(p["wi"], x, dtype)
    else:
        h = jax.nn.gelu(dense(p["wi"], x, dtype))
    return dense(p["wo"], h, dtype)


# --- rotary ---------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..,S,hd/2)
    cos = jnp.cos(ang)[..., :, None, :]               # (..,S,1,hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Stable CE over the last axis; logits float32 recommended."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
