"""RecSys models: DeepFM / DCN-v2 / xDeepFM / two-tower retrieval.

The hot path is the embedding lookup over huge tables. JAX has no
EmbeddingBag: it is built here from ``jnp.take`` + segment ops (taxonomy
§RecSys), with a model-parallel shard_map variant in
``repro.distributed.embedding`` (row-sharded tables, psum combine) and a
Pallas TPU kernel in ``repro.kernels.embedding_bag``.

All 39/26 sparse fields share one combined table (row offset per field)
so the lookup is a single gather from one row-sharded array.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.layers import dense, dense_init

Params = Dict[str, jnp.ndarray]


def table_rows(cfg: RecsysConfig) -> int:
    return cfg.n_sparse * cfg.rows_per_field


def field_offsets(cfg: RecsysConfig) -> jnp.ndarray:
    return (jnp.arange(cfg.n_sparse, dtype=jnp.int32)
            * cfg.rows_per_field)


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                     cfg: RecsysConfig) -> jnp.ndarray:
    """ids (B, F) field-local -> (B, F, D) via one combined-table gather."""
    flat = ids + field_offsets(cfg)[None, :]
    return jnp.take(table, flat, axis=0)


def _mlp_init(key, dims: Tuple[int, ...]) -> Params:
    ks = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": dense_init(ks[i], dims[i], dims[i + 1], bias=True)
            for i in range(len(dims) - 1)}


def _mlp_apply(p: Params, x: jnp.ndarray, *, final_act: bool = False
               ) -> jnp.ndarray:
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x, dtype=jnp.float32)
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# init / forward per interaction type
# ---------------------------------------------------------------------------


def init_params(cfg: RecsysConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    rows = table_rows(cfg)
    p: Params = {
        "table": jax.random.normal(ks[0], (rows, cfg.embed_dim),
                                   jnp.float32) * 0.01,
    }
    d_emb = cfg.n_sparse * cfg.embed_dim
    d_in = cfg.n_dense + d_emb
    if cfg.interaction == "fm":
        p["linear_table"] = jax.random.normal(ks[1], (rows, 1),
                                              jnp.float32) * 0.01
        p["mlp"] = _mlp_init(ks[2], (d_in,) + cfg.mlp + (1,))
    elif cfg.interaction == "cross":
        for i in range(cfg.n_cross_layers):
            p[f"cross_w{i}"] = dense_init(ks[2 + i % 4], d_in, d_in,
                                          bias=True)
        p["mlp"] = _mlp_init(ks[6], (d_in,) + cfg.mlp + (1,))
    elif cfg.interaction == "cin":
        f0 = cfg.n_sparse
        prev = f0
        for i, hk in enumerate(cfg.cin_layers):
            p[f"cin_w{i}"] = jax.random.normal(
                jax.random.fold_in(ks[2], i), (hk, prev, f0),
                jnp.float32) * (1.0 / np.sqrt(prev * f0))
            prev = hk
        p["cin_out"] = dense_init(ks[3], sum(cfg.cin_layers), 1, bias=True)
        p["mlp"] = _mlp_init(ks[4], (d_in,) + cfg.mlp + (1,))
    elif cfg.interaction == "dot":     # two-tower
        d_feat = (cfg.n_sparse // 2) * cfg.embed_dim
        p["user_mlp"] = _mlp_init(ks[2], (d_feat,) + cfg.tower_mlp)
        p["item_mlp"] = _mlp_init(ks[3], (d_feat,) + cfg.tower_mlp)
    else:
        raise ValueError(cfg.interaction)
    return p


def forward(cfg: RecsysConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """CTR models -> (B,) logit. Two-tower handled separately."""
    emb = embedding_lookup(params["table"], batch["sparse"], cfg)  # (B,F,D)
    b = emb.shape[0]
    flat = emb.reshape(b, -1)
    x0 = jnp.concatenate([batch["dense"], flat], axis=1) \
        if cfg.n_dense else flat

    if cfg.interaction == "fm":
        lin = embedding_lookup(params["linear_table"], batch["sparse"],
                               dataclass_like(cfg)).sum(axis=(1, 2))
        sv = emb.sum(axis=1)                         # (B, D)
        fm = 0.5 * jnp.sum(sv * sv - jnp.sum(emb * emb, axis=1), axis=1)
        deep = _mlp_apply(params["mlp"], x0)[:, 0]
        return lin + fm + deep
    if cfg.interaction == "cross":
        x = x0
        for i in range(cfg.n_cross_layers):
            xw = dense(params[f"cross_w{i}"], x, dtype=jnp.float32)
            x = x0 * xw + x
        return _mlp_apply(params["mlp"], x)[:, 0]
    if cfg.interaction == "cin":
        xk = emb                                      # (B, Hk, D)
        outs = []
        for i in range(len(cfg.cin_layers)):
            z = jnp.einsum("bhd,bfd->bhfd", xk, emb)
            xk = jnp.einsum("bhfd,ohf->bod", z, params[f"cin_w{i}"])
            outs.append(xk.sum(-1))                   # (B, Hk)
        cin = dense(params["cin_out"], jnp.concatenate(outs, 1),
                    dtype=jnp.float32)[:, 0]
        deep = _mlp_apply(params["mlp"], x0)[:, 0]
        return cin + deep
    raise ValueError(cfg.interaction)


def dataclass_like(cfg: RecsysConfig) -> RecsysConfig:
    """cfg clone whose embed dim matches the 1-wide linear table."""
    import dataclasses
    return dataclasses.replace(cfg, embed_dim=1)


def loss_fn(cfg: RecsysConfig, params: Params,
            batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    if cfg.interaction == "dot":
        return two_tower_loss(cfg, params, batch)
    logit = forward(cfg, params, batch)
    y = batch["label"]
    loss = jnp.mean(jnp.maximum(logit, 0) - logit * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return loss, {"logit_mean": logit.mean()}


# ---------------------------------------------------------------------------
# two-tower retrieval
# ---------------------------------------------------------------------------


def tower_embeddings(cfg: RecsysConfig, params: Params,
                     batch: Dict[str, jnp.ndarray]
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    half = cfg.n_sparse // 2
    emb = embedding_lookup(params["table"], batch["sparse"], cfg)
    b = emb.shape[0]
    u = _mlp_apply(params["user_mlp"], emb[:, :half].reshape(b, -1))
    v = _mlp_apply(params["item_mlp"], emb[:, half:].reshape(b, -1))
    u = u / jnp.maximum(jnp.linalg.norm(u, axis=1, keepdims=True), 1e-6)
    v = v / jnp.maximum(jnp.linalg.norm(v, axis=1, keepdims=True), 1e-6)
    return u, v


def two_tower_loss(cfg: RecsysConfig, params: Params,
                   batch: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict]:
    """In-batch sampled softmax (RecSys'19) with temperature."""
    u, v = tower_embeddings(cfg, params, batch)
    logits = (u @ v.T) / 0.05                        # (B, B)
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=1)
    loss = jnp.mean(lse - jnp.diag(logits))
    acc = jnp.mean(jnp.argmax(logits, 1) == labels)
    return loss, {"acc": acc}


@functools.partial(jax.jit, static_argnames=("k",))
def score_candidates(user_emb: jnp.ndarray, cand_emb: jnp.ndarray,
                     k: int = 100) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """retrieval_cand brute-force path: (Q,D)x(C,D) -> top-k.

    The IVF early-exit path for the same cell lives in
    ``repro.core.ivf.search`` — the paper's technique applied to this
    architecture (DESIGN §4).
    """
    scores = user_emb @ cand_emb.T
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)


def serve_logits(cfg: RecsysConfig, params: Params,
                 batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Pointwise online/offline scoring (serve_p99 / serve_bulk)."""
    if cfg.interaction == "dot":
        u, v = tower_embeddings(cfg, params, batch)
        return jnp.sum(u * v, axis=1)
    return forward(cfg, params, batch)
