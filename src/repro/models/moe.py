"""Mixture-of-Experts FFN: sort-based capacity dispatch + EP sharding.

No O(T·E·C) one-hot dispatch matmul (DESIGN §5): tokens are argsorted by
expert, ranked within expert, and scattered into an (E, capacity, d)
buffer that is sharding-constrained to the `model` axis — the SPMD
partitioner turns the re-layout into the MoE all-to-all. Covers both
DBRX (16e top-4) and DeepSeekMoE (2 shared + 64 routed top-6,
first layer dense).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig, TransformerConfig
from repro.distributed import context as ctx
from repro.models.layers import dense, dense_init, mlp, mlp_init

Params = Dict[str, jnp.ndarray]


def moe_init(key, cfg: TransformerConfig) -> Params:
    mo = cfg.moe
    d, ff = cfg.d_model, mo.d_ff_expert
    e = mo.n_experts
    ks = jax.random.split(key, 6)
    scale_in = 1.0 / np.sqrt(d)
    scale_out = 1.0 / np.sqrt(ff)
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "wi": jax.random.normal(ks[1], (e, d, ff), jnp.float32) * scale_in,
        "wg": jax.random.normal(ks[2], (e, d, ff), jnp.float32) * scale_in,
        "wo": jax.random.normal(ks[3], (e, ff, d), jnp.float32) * scale_out,
    }
    if mo.n_shared:
        p["shared"] = mlp_init(ks[4], d, mo.n_shared * ff, "swiglu")
    return p


def _dispatch_indices(expert_idx: jnp.ndarray, n_experts: int, capacity: int
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """expert_idx: (T*K,) -> (slot index into E*C, keep mask, perm)."""
    tk = expert_idx.shape[0]
    perm = jnp.argsort(expert_idx)                      # stable
    sorted_e = jnp.take(expert_idx, perm)
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts),
                              side="left")
    rank = jnp.arange(tk) - jnp.take(starts, sorted_e)
    keep = rank < capacity
    # dropped tokens get an out-of-range slot: scatter mode="drop" skips
    # them (a clamped slot would clobber the last valid entry)
    slot = jnp.where(keep, sorted_e * capacity + rank,
                     n_experts * capacity)
    return slot, keep, perm


def moe_forward(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,d) -> (out, aux_loss).

    Under a mesh, the token-local work (routing, argsort, capacity
    scatter) runs inside a shard_map over the DP axes — argsort on a
    globally-sharded token dim would otherwise force XLA to all-gather
    every token (observed: 100+GB dispatch buffers). The `model` axis
    stays auto inside (EP all-to-all via sharding constraints)."""
    mesh = ctx.current_mesh()
    dp = ctx.dp_axes()
    if mesh is None or dp is None:
        return _moe_core(p, x, cfg)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if x.shape[0] % dp_size:        # tiny/unsharded batch (B=1 decode)
        return _moe_core(p, x, cfg)
    from jax.sharding import PartitionSpec as P

    def body(xl, pl):
        # ZeRO-3 gather-on-use: expert weights enter FSDP-sharded on
        # their d/ff dim (in_specs below) and are all-gathered in bf16
        # per use; the transpose of the gather reduce-scatters the
        # expert grads back to shards. The E dim stays auto ('model').
        pl = dict(pl)
        for name, dim in (("wi", 1), ("wg", 1), ("wo", 1)):
            w = pl[name].astype(jnp.bfloat16)
            for a in dp:
                w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
            pl[name] = w
        out, aux = _moe_core(pl, xl, cfg)
        # aux returned per-shard (averaged outside) — a scalar pmean
        # inside a partial-auto shard_map trips an XLA:CPU
        # AllReducePromotion crash
        return out, aux.reshape(1)

    p_specs = {k: (P(None, dp, None) if k in ("wi", "wg", "wo")
                   else jax.tree.map(lambda _: P(), v))
               for k, v in p.items()}
    sm = jax.shard_map(body, mesh=mesh,
                       in_specs=(P(dp, None, None), p_specs),
                       out_specs=(P(dp, None, None), P(dp)),
                       axis_names=set(dp))
    out, aux_shards = sm(x, p)
    return out, jnp.mean(aux_shards)


def _moe_core(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = mo.n_experts, mo.top_k
    # capacity floor: at small T (decode) a ceil() of <8 drops tokens
    # catastrophically; min(t*k, 8) guarantees drop-free tiny batches.
    capacity = int(max(np.ceil(t * k / e * mo.capacity_factor),
                       min(t * k, 8)))
    xt = x.reshape(t, d)

    logits = dense(p["router"], xt, dtype=jnp.float32)      # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # (T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * mean(frac_tokens * frac_prob)
    frac_prob = probs.mean(0)
    counts = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    frac_tok = counts / (t * k)
    aux = e * jnp.sum(frac_prob * frac_tok) * mo.router_aux_weight

    flat_e = top_e.reshape(-1)
    slot, keep, perm = _dispatch_indices(flat_e, e, capacity)
    tok_of = perm // k                                      # token per slot
    gathered = jnp.take(xt, tok_of, axis=0)                 # (T*K, d)
    gathered = ctx.act(gathered, ("model", None))
    buf = jnp.zeros((e * capacity, d), xt.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], gathered, 0.0),
                           mode="drop")
    buf = buf.reshape(e, capacity, d)
    buf = ctx.act(buf, ("model", None, None))

    bh = buf.astype(jnp.bfloat16)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bh,
                               p["wg"].astype(jnp.bfloat16))) * \
        jnp.einsum("ecd,edf->ecf", bh, p["wi"].astype(jnp.bfloat16))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(jnp.bfloat16))
    out_buf = ctx.act(out_buf, ("model", None, None))

    back = jnp.take(out_buf.reshape(e * capacity, d), slot, axis=0,
                    mode="clip")  # dropped slots are OOB; weight==0 below
    back = ctx.act(back, ("model", None))
    w = jnp.take(top_p.reshape(-1), perm) * keep
    contrib = back * w[:, None].astype(back.dtype)
    out = jnp.zeros((t, d), back.dtype).at[tok_of].add(contrib)

    if mo.n_shared:
        out = out + mlp(p["shared"], xt, "swiglu")
    return out.reshape(b, s, d), aux


