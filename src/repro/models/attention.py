"""Attention: chunked-causal (flash-style) training path, GQA + MLA,
decode steps over bf16/int8 KV caches.

The chunked path scans query blocks so the (chunk, S) score tile is the
peak intermediate — never the full (S, S) matrix (required for the
prefill_32k cells). The Pallas flash kernel in ``repro.kernels`` is the
TPU-target replacement for the inner block; this jnp path is the oracle
and the dry-run lowering.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig, TransformerConfig
from repro.distributed.context import act, model_size
from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, \
    rmsnorm_init

Params = Dict[str, jnp.ndarray]
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: TransformerConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim()
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d),
    }


def _causal_chunk_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       chunk: int) -> jnp.ndarray:
    """Flat-head chunked causal attention.

    q: (B,S,H,hd); k: (B,S,H,hd) (GQA KV repeated to H by the caller so
    the 'model' sharding lands uniformly on the head axis — Megatron
    style; the repeat is transient and head-sharded); v: (B,S,H,vd).
    Peak intermediate = one (H, chunk, S) score tile per scan step.
    """
    b, s, h, hd = q.shape
    chunk = min(chunk, s)
    s_pad = ((s + chunk - 1) // chunk) * chunk
    if s_pad != s:  # pad queries only; padded rows are sliced off below
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    n_chunks = s_pad // chunk
    scale = 1.0 / np.sqrt(hd)
    qc = q.reshape(b, n_chunks, chunk, h, hd)
    kpos = jnp.arange(k.shape[1])

    def step(_, inp):
        qi, i = inp                                # (B,chunk,H,hd), ()
        qpos = i * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bchd,bshd->bhcs", qi.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        logits = act(logits, ("dp", "model", None, None))
        mask = kpos[None, :] <= qpos[:, None]      # (chunk, S)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhcs,bshv->bchv", p.astype(v.dtype), v)
        o = act(o, ("dp", None, "model", None))
        return None, o

    _, out = jax.lax.scan(step, None,
                          (qc.swapaxes(0, 1), jnp.arange(n_chunks)))
    vd = v.shape[-1]
    out = out.swapaxes(0, 1).reshape(b, s_pad, h, vd)
    return out[:, :s] if s_pad != s else out


def gqa_forward(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
                positions: jnp.ndarray) -> jnp.ndarray:
    b, s, d = x.shape
    hd, h, kv = cfg.head_dim(), cfg.n_heads, cfg.n_kv_heads
    g = h // kv
    q = dense(p["wq"], x).reshape(b, s, h, hd)
    k = dense(p["wk"], x).reshape(b, s, kv, hd)
    v = dense(p["wv"], x).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if g > 1:  # repeat KV heads to H (transient, head-sharded)
        if kv % max(model_size(), 1):
            # kv doesn't divide TP: disambiguate (replicate the small
            # head dim) BEFORE the repeat, else the partitioner emits
            # involuntary full-remat copies (and trips an XLA:CPU
            # AllReducePromotion crash)
            k = act(k, ("dp", None, None, None))
            v = act(v, ("dp", None, None, None))
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = act(q, ("dp", None, "model", None))
    k = act(k, ("dp", None, "model", None))
    v = act(v, ("dp", None, "model", None))
    o = _causal_chunk_attn(q, k, v, cfg.attn_chunk)
    o = act(o, ("dp", None, "model", None))
    return dense(p["wo"], o.reshape(b, s, h * hd))


# ---------------------------------------------------------------------------
# KV cache (bf16 / int8) + decode
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray                  # (L,B,Smax,KV,hd) bf16 or int8
    v: jnp.ndarray
    k_scale: Optional[jnp.ndarray]  # (L,B,Smax,KV) f32 (int8 only)
    v_scale: Optional[jnp.ndarray]


def init_kv_cache(cfg: TransformerConfig, batch: int, max_seq: int
                  ) -> KVCache:
    hd, kv, L = cfg.head_dim(), cfg.n_kv_heads, cfg.n_layers
    if cfg.kv_cache_dtype == "int8":
        z = jnp.zeros((L, batch, max_seq, kv, hd), jnp.int8)
        sc = jnp.ones((L, batch, max_seq, kv), jnp.float32)
        return KVCache(z, z, sc, sc)
    z = jnp.zeros((L, batch, max_seq, kv, hd), jnp.bfloat16)
    return KVCache(z, z, None, None)


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(…, hd) -> int8 data + per-vector scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(jnp.bfloat16)


def cache_update(layer_k: jnp.ndarray, layer_scale: Optional[jnp.ndarray],
                 new: jnp.ndarray, pos: jnp.ndarray, *, use_dus: bool = True
                 ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Write (B,1,KV,hd) at seq position ``pos``.

    use_dus: dynamic_update_slice (owning-shard write). The masked-select
    alternative (full rewrite) is kept for the §Perf ablation.
    """
    if layer_scale is not None:
        qv, sc = quantize_kv(new)
        if use_dus:
            k = jax.lax.dynamic_update_slice(
                layer_k, qv, (0, pos, 0, 0))
            s = jax.lax.dynamic_update_slice(
                layer_scale, sc, (0, pos, 0))
        else:
            smax = layer_k.shape[1]
            m = (jnp.arange(smax) == pos)[None, :, None, None]
            k = jnp.where(m, qv, layer_k)
            s = jnp.where(m[..., 0], sc, layer_scale)
        return k, s
    new = new.astype(layer_k.dtype)
    if use_dus:
        return jax.lax.dynamic_update_slice(layer_k, new, (0, pos, 0, 0)), None
    smax = layer_k.shape[1]
    m = (jnp.arange(smax) == pos)[None, :, None, None]
    return jnp.where(m, new, layer_k), None


def decode_attn(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                k_scale, v_scale, pos: jnp.ndarray) -> jnp.ndarray:
    """q:(B,1,KV,G,hd); caches (B,Smax,KV,hd) -> (B,1,KV,G,hd).

    Written reduction-first so the SPMD partitioner turns a seq-sharded
    cache into local partial softmax stats + a tiny psum (DESIGN §5).
    """
    b, _, kv, g, hd = q.shape
    smax = k_cache.shape[1]
    scale = 1.0 / np.sqrt(hd)
    # int8 KV: the per-position scales are folded AFTER the QK dot (for
    # K) and INTO the probabilities (for V), so the dequantized
    # (B,S,KV,hd) f32 cache is never materialised — only the small
    # (B,KV,G,1,S) logits carry the correction.
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.bfloat16),
                        k_cache.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32) * scale
    if k_scale is not None:
        logits = logits * jnp.moveaxis(
            k_scale.astype(jnp.float32), 1, 2)[:, :, None, None, :]
    mask = (jnp.arange(smax) <= pos)[None, None, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    if v_scale is not None:
        p = p * jnp.moveaxis(
            v_scale.astype(jnp.float32), 1, 2)[:, :, None, None, :]
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def gqa_decode(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
               layer_cache, pos: jnp.ndarray):
    """x: (B,1,d); layer_cache: (k, v, k_scale, v_scale) for this layer."""
    b, s, d = x.shape
    hd, h, kv = cfg.head_dim(), cfg.n_heads, cfg.n_kv_heads
    g = h // kv
    lk, lv, lks, lvs = layer_cache
    q = dense(p["wq"], x).reshape(b, 1, h, hd)
    k = dense(p["wk"], x).reshape(b, 1, kv, hd)
    v = dense(p["wv"], x).reshape(b, 1, kv, hd)
    posv = jnp.full((b, 1), pos)
    q = apply_rope(q, posv, cfg.rope_theta).reshape(b, 1, kv, g, hd)
    k = apply_rope(k, posv, cfg.rope_theta)
    lk, lks = cache_update(lk, lks, k, pos)
    lv, lvs = cache_update(lv, lvs, v, pos)
    o = decode_attn(q, lk, lv, lks, lvs, pos)
    out = dense(p["wo"], o.reshape(b, 1, h * hd))
    return out, (lk, lv, lks, lvs)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-style latent attention) — MiniCPM3
# ---------------------------------------------------------------------------


def mla_init(key, cfg: TransformerConfig) -> Params:
    m = cfg.mla or MLAConfig()
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank),
        "q_norm": rmsnorm_init(m.q_lora_rank),
        "wq_b": dense_init(ks[1], m.q_lora_rank, h * qk),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim),
        "kv_norm": rmsnorm_init(m.kv_lora_rank),
        "wk_b": dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim),
        "wv_b": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim),
        "wo": dense_init(ks[5], h * m.v_head_dim, d),
    }


def _mla_qkv(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
             positions: jnp.ndarray):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(p["q_norm"], dense(p["wq_a"], x))
    q = dense(p["wq_b"], cq).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    kv = dense(p["wkv_a"], x)
    ckv = rmsnorm(p["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = apply_rope(kv[..., m.kv_lora_rank:][:, :, None, :], positions,
                        cfg.rope_theta)                   # (B,S,1,rope)
    return q_nope, q_rope, ckv, k_rope


def mla_forward(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
                positions: jnp.ndarray) -> jnp.ndarray:
    """Training path: expand latent to per-head K/V, chunked causal attn."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
    ckv = act(ckv, ("dp", None, None))
    k_nope = dense(p["wk_b"], ckv).reshape(b, s, h, m.qk_nope_head_dim)
    v = dense(p["wv_b"], ckv).reshape(b, s, h, m.v_head_dim)
    k_nope = act(k_nope, ("dp", None, "model", None))
    v = act(v, ("dp", None, "model", None))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))], -1)
    q = act(q, ("dp", None, "model", None))
    k = act(k, ("dp", None, "model", None))
    v = act(v, ("dp", None, "model", None))
    o = _causal_chunk_attn(q, k, v, cfg.attn_chunk)
    o = act(o, ("dp", None, "model", None))
    return dense(p["wo"], o.reshape(b, s, h * m.v_head_dim))


class MLACache(NamedTuple):
    ckv: jnp.ndarray     # (L,B,Smax,r)
    k_rope: jnp.ndarray  # (L,B,Smax,rope)


def init_mla_cache(cfg: TransformerConfig, batch: int, max_seq: int
                   ) -> MLACache:
    m = cfg.mla
    return MLACache(
        jnp.zeros((cfg.n_layers, batch, max_seq, m.kv_lora_rank),
                  jnp.bfloat16),
        jnp.zeros((cfg.n_layers, batch, max_seq, m.qk_rope_head_dim),
                  jnp.bfloat16))


def mla_decode(p: Params, x: jnp.ndarray, cfg: TransformerConfig,
               layer_cache, pos: jnp.ndarray):
    """Absorbed-matrix MLA decode (DeepSeek-V2 §: O(S·r) per step —
    attention runs entirely in the latent space)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    ckv_c, kr_c = layer_cache                       # (B,Smax,r), (B,Smax,rope)
    posv = jnp.full((b, 1), pos)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, posv)
    ckv_c = jax.lax.dynamic_update_slice(
        ckv_c, ckv.astype(ckv_c.dtype), (0, pos, 0))
    kr_c = jax.lax.dynamic_update_slice(
        kr_c, k_rope[:, :, 0, :].astype(kr_c.dtype), (0, pos, 0))
    wk_b = p["wk_b"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    # absorb W_uk into the query:  q_lat[b,h,r] = q_nope · W_uk
    q_lat = jnp.einsum("bqhn,rhn->bhqr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))    # (B,H,1,r)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (jnp.einsum("bhqr,bsr->bhqs", q_lat,
                         ckv_c.astype(jnp.float32))
              + jnp.einsum("bqhp,bsp->bhqs", q_rope.astype(jnp.float32),
                           kr_c.astype(jnp.float32))) * scale
    smax = ckv_c.shape[1]
    mask = (jnp.arange(smax) <= pos)[None, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    prob = jax.nn.softmax(logits, axis=-1)
    lat = jnp.einsum("bhqs,bsr->bhqr", prob, ckv_c.astype(jnp.float32))
    wv_b = p["wv_b"]["w"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhqr,rhv->bqhv", lat, wv_b.astype(jnp.float32))
    out = dense(p["wo"], o.reshape(b, 1, h * m.v_head_dim)
                .astype(jnp.bfloat16))
    return out, (ckv_c, kr_c)
