"""GAT (and mean/sum/max aggregators) via edge-list segment ops.

JAX has no CSR SpMM — message passing is built from
``jax.ops.segment_sum`` / ``segment_max`` over an edge index, which IS
the system (taxonomy §GNN, SpMM/SDDMM regime):
  SDDMM  = per-edge attention logits (gather src/dst features)
  softmax= segment_max + segment_sum over incoming edges per dst
  SpMM   = alpha-weighted segment_sum of source features.

Shapes: full-graph (Cora / ogbn-products), sampled minibatch blocks
(fanout sampler in ``repro.data.graph_sampler``), and batched small
graphs (disjoint-union batching) all share this one layer.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.layers import cross_entropy_loss

Params = Dict[str, jnp.ndarray]


class Graph(NamedTuple):
    feat: jnp.ndarray       # (N, F)
    edge_src: jnp.ndarray   # (E,) int32
    edge_dst: jnp.ndarray   # (E,) int32
    label: jnp.ndarray      # (N,) int32, -1 = unlabeled
    edge_mask: Optional[jnp.ndarray] = None  # (E,) bool for padded edges


def gat_layer_init(key, d_in: int, d_out: int, n_heads: int) -> Params:
    ks = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(d_in)
    return {
        "w": jax.random.normal(ks[0], (d_in, n_heads, d_out),
                               jnp.float32) * s,
        "a_src": jax.random.normal(ks[1], (n_heads, d_out),
                                   jnp.float32) * 0.1,
        "a_dst": jax.random.normal(ks[2], (n_heads, d_out),
                                   jnp.float32) * 0.1,
        "b": jnp.zeros((n_heads, d_out), jnp.float32),
    }


def gat_layer(p: Params, g: Graph, x: jnp.ndarray, *, n_nodes: int,
              aggregator: str = "attn", final: bool = False) -> jnp.ndarray:
    """x: (N, F) -> (N, heads*d_out) (concat) or (N, d_out) (final mean)."""
    h = jnp.einsum("nf,fhd->nhd", x, p["w"])             # (N, H, D)
    src_h = jnp.take(h, g.edge_src, axis=0)              # (E, H, D)
    if aggregator == "attn":
        # SDDMM: edge logits
        e_src = jnp.take(jnp.einsum("nhd,hd->nh", h, p["a_src"]),
                         g.edge_src, axis=0)
        e_dst = jnp.take(jnp.einsum("nhd,hd->nh", h, p["a_dst"]),
                         g.edge_dst, axis=0)
        logits = jax.nn.leaky_relu(e_src + e_dst, 0.2)   # (E, H)
        if g.edge_mask is not None:
            logits = jnp.where(g.edge_mask[:, None], logits, -1e30)
        # segment softmax over incoming edges of each dst
        mx = jax.ops.segment_max(logits, g.edge_dst, num_segments=n_nodes)
        ex = jnp.exp(logits - jnp.take(mx, g.edge_dst, axis=0))
        if g.edge_mask is not None:
            ex = jnp.where(g.edge_mask[:, None], ex, 0.0)
        den = jax.ops.segment_sum(ex, g.edge_dst, num_segments=n_nodes)
        alpha = ex / jnp.maximum(jnp.take(den, g.edge_dst, axis=0), 1e-9)
        msg = src_h * alpha[..., None]
        out = jax.ops.segment_sum(msg, g.edge_dst, num_segments=n_nodes)
    elif aggregator in ("mean", "sum"):
        m = src_h if g.edge_mask is None else \
            src_h * g.edge_mask[:, None, None]
        out = jax.ops.segment_sum(m, g.edge_dst, num_segments=n_nodes)
        if aggregator == "mean":
            ones = jnp.ones((g.edge_src.shape[0],), x.dtype) if \
                g.edge_mask is None else g.edge_mask.astype(x.dtype)
            deg = jax.ops.segment_sum(ones, g.edge_dst,
                                      num_segments=n_nodes)
            out = out / jnp.maximum(deg, 1.0)[:, None, None]
    elif aggregator == "max":
        m = src_h if g.edge_mask is None else \
            jnp.where(g.edge_mask[:, None, None], src_h, -1e30)
        out = jax.ops.segment_max(m, g.edge_dst, num_segments=n_nodes)
        out = jnp.maximum(out, -1e29)
    else:
        raise ValueError(aggregator)
    out = out + p["b"]
    if final:
        return out.mean(axis=1)                          # average heads
    return jax.nn.elu(out).reshape(n_nodes, -1)          # concat heads


def init_params(cfg: GNNConfig, key) -> Params:
    ks = jax.random.split(key, cfg.n_layers)
    p: Params = {}
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        p[f"layer_{i}"] = gat_layer_init(ks[i], d_in, d_out, cfg.n_heads)
        d_in = cfg.d_hidden * cfg.n_heads
    return p


def forward(cfg: GNNConfig, params: Params, g: Graph) -> jnp.ndarray:
    n = g.feat.shape[0]
    x = g.feat
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        x = gat_layer(params[f"layer_{i}"], g, x, n_nodes=n,
                      aggregator=cfg.aggregator, final=last)
    return x                                             # (N, n_classes)


def forward_blocks(cfg: GNNConfig, params: Params, feats: jnp.ndarray,
                   blocks, n_outs: Tuple[int, ...]) -> jnp.ndarray:
    """Minibatch path over sampled blocks (outermost-first list of
    array-dicts; see repro.data.graph_sampler). feats: features of
    blocks[-1].nodes; n_outs: static per-block output-prefix sizes."""
    x = feats
    for i in range(cfg.n_layers):
        b = blocks[-1 - i]              # innermost block = first layer
        n_in = x.shape[0]
        g = Graph(feat=x, edge_src=b["edge_src"], edge_dst=b["edge_dst"],
                  label=jnp.zeros((n_in,), jnp.int32),
                  edge_mask=b["edge_mask"])
        last = i == cfg.n_layers - 1
        x = gat_layer(params[f"layer_{i}"], g, x, n_nodes=n_in,
                      aggregator=cfg.aggregator, final=last)
        x = x[: n_outs[len(blocks) - 1 - i]]
    return x


def loss_blocks(cfg: GNNConfig, params: Params, feats: jnp.ndarray,
                blocks, labels: jnp.ndarray,
                n_outs: Tuple[int, ...]) -> Tuple[jnp.ndarray, Dict]:
    logits = forward_blocks(cfg, params, feats, blocks, n_outs)
    mask = (labels >= 0).astype(jnp.float32)
    ce = cross_entropy_loss(logits, jnp.maximum(labels, 0), mask)
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / \
        jnp.maximum(mask.sum(), 1.0)
    return ce, {"acc": acc}


def loss_fn(cfg: GNNConfig, params: Params, g: Graph
            ) -> Tuple[jnp.ndarray, Dict]:
    logits = forward(cfg, params, g)
    mask = (g.label >= 0).astype(jnp.float32)
    ce = cross_entropy_loss(logits, jnp.maximum(g.label, 0), mask)
    acc = jnp.sum((jnp.argmax(logits, -1) == g.label) * mask) / \
        jnp.maximum(mask.sum(), 1.0)
    return ce, {"acc": acc}
