"""Decoder-only LM: scan-over-layers, GQA/MLA attention, optional MoE.

Entry points used by the launcher / dry-run:
  init_params(cfg, key)          -> pytree (fp32 master weights)
  loss_fn(cfg, params, batch)    -> scalar loss (train_step lowers this)
  prefill(cfg, params, tokens)   -> (last-token logits, KV/MLA cache)
  decode_step(cfg, params, cache, token, pos) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TransformerConfig
from repro.distributed.context import act
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import (cross_entropy_loss, dense, dense_init, mlp,
                                 mlp_init, rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: TransformerConfig, *, dense_ffn: bool) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model),
                 "ln2": rmsnorm_init(cfg.d_model)}
    if cfg.attn_type == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg)
    else:
        p["attn"] = attn.gqa_init(ks[0], cfg)
    if cfg.moe is not None and not dense_ffn:
        p["moe"] = moe_lib.moe_init(ks[1], cfg)
    else:
        d_ff = (cfg.moe.d_ff_dense if (cfg.moe and dense_ffn) else cfg.d_ff)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, d_ff, cfg.mlp_type)
    return p


def init_params(cfg: TransformerConfig, key) -> Params:
    p = _init_params_f32(cfg, key)
    if cfg.param_dtype == "bfloat16":
        p = jax.tree.map(lambda a: a.astype(jnp.bfloat16)
                         if a.dtype == jnp.float32 else a, p)
    return p


def _init_params_f32(cfg: TransformerConfig, key) -> Params:
    ks = jax.random.split(key, 4)
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0
    n_scan = cfg.n_layers - n_dense
    layer_keys = jax.random.split(ks[0], n_scan)
    stacked = jax.vmap(
        lambda k: _layer_init(k, cfg, dense_ffn=False))(layer_keys)
    p: Params = {
        "embed": jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02,
        "ln_f": rmsnorm_init(cfg.d_model),
        "layers": stacked,
    }
    for i in range(n_dense):
        p[f"dense_layer_{i}"] = _layer_init(
            jax.random.fold_in(ks[2], i), cfg, dense_ffn=True)
    if not cfg.tie_embeddings:
        p["out"] = dense_init(ks[3], cfg.d_model, cfg.vocab_size)
    return p


def abstract_params(cfg: TransformerConfig) -> Params:
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block(cfg: TransformerConfig, lp: Params, x: jnp.ndarray,
           positions: jnp.ndarray, *, dense_ffn: bool
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = attn.mla_forward(lp["attn"], h, cfg, positions)
    else:
        a = attn.gqa_forward(lp["attn"], h, cfg, positions)
    x = x + a
    x = act(x, ("dp", "model", None), bf16_cotangent=True)
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp and not dense_ffn:
        f, aux = moe_lib.moe_forward(lp["moe"], h, cfg)
    else:
        f = mlp(lp["mlp"], h, cfg.mlp_type)
    return act(x + f, ("dp", "model", None), bf16_cotangent=True), aux


def forward_hidden(cfg: TransformerConfig, params: Params,
                   tokens: jnp.ndarray, *, remat: bool = False,
                   unroll: bool = False
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S) -> (final hidden (B,S,d) post-norm, aux loss)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    x = act(x, ("dp", "model", None))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0
    for i in range(n_dense):
        x, aux = _block(cfg, params[f"dense_layer_{i}"], x, positions,
                        dense_ffn=True)
        aux_total = aux_total + aux

    block = functools.partial(_block, cfg, positions=positions,
                              dense_ffn=False)
    policy = (jax.checkpoint_policies.dots_saveable
              if cfg.remat_policy == "dots"
              else jax.checkpoint_policies.nothing_saveable)
    body = (jax.checkpoint(lambda lp, x: block(lp, x), policy=policy)
            if remat else (lambda lp, x: block(lp, x)))

    def scan_fn(carry, lp):
        x, aux_sum = carry
        x, aux = body(lp, x)
        return (x, aux_sum + aux), None

    (x, aux_total), _ = jax.lax.scan(scan_fn, (x, aux_total),
                                     params["layers"], unroll=unroll)
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), aux_total


def forward(cfg: TransformerConfig, params: Params, tokens: jnp.ndarray,
            *, remat: bool = False, unroll: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B,S) -> (logits f32 (B,S,V), total aux loss). Test /
    small-scale path; training uses loss_fn's chunked CE instead."""
    x, aux_total = forward_hidden(cfg, params, tokens, remat=remat,
                                  unroll=unroll)
    return _head_logits(cfg, params, x), aux_total


def _head_logits(cfg: TransformerConfig, params: Params, x: jnp.ndarray
                 ) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x.astype(jnp.float32) @ params["embed"].T
    return dense(params["out"], x, dtype=jnp.float32)


def loss_fn(cfg: TransformerConfig, params: Params,
            batch: Dict[str, jnp.ndarray], *, unroll: bool = False,
            ce_chunk: int = 512) -> Tuple[jnp.ndarray, Dict]:
    """Chunked cross-entropy: the (B, S, V) logits tensor is never
    materialised — the head matmul + CE run per (B, ce_chunk) token
    slab under remat (peak extra memory = B * ce_chunk * V / shards)."""
    hidden, aux = forward_hidden(cfg, params, batch["tokens"], remat=True,
                                 unroll=unroll)
    b, s, _ = hidden.shape
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    chunk = min(ce_chunk, s)
    n_chunks = s // chunk if s % chunk == 0 else 1
    if s % chunk != 0:
        chunk = s

    hc = hidden.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
    lc = jnp.maximum(labels, 0).reshape(b, n_chunks, chunk).swapaxes(0, 1)
    mc = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_nll(xc, labc, mkc):
        logits = _head_logits(cfg, params, xc)
        logits = act(logits, ("dp", None, "model"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mkc)

    def scan_fn(carry, inp):
        xc, labc, mkc = inp
        return carry + chunk_nll(xc, labc, mkc), None

    total, _ = jax.lax.scan(scan_fn, jnp.zeros((), jnp.float32),
                            (hc, lc, mc), unroll=unroll)
    ce = total / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


class LMCache:
    """KV cache container; ``kind`` ("gqa"|"gqa8"|"mla") rides in pytree
    aux-data, ``data`` are the stacked (L, ...) cache arrays."""

    def __init__(self, kind: str, data: Tuple[jnp.ndarray, ...]):
        self.kind = kind
        self.data = tuple(data)

    def __repr__(self):
        return f"LMCache({self.kind}, {[a.shape for a in self.data]})"


jax.tree_util.register_pytree_node(
    LMCache, lambda c: (c.data, c.kind),
    lambda kind, children: LMCache(kind, tuple(children)))


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int) -> LMCache:
    if cfg.attn_type == "mla":
        c = attn.init_mla_cache(cfg, batch, max_seq)
        return LMCache("mla", (c.ckv, c.k_rope))
    c = attn.init_kv_cache(cfg, batch, max_seq)
    if c.k_scale is not None:
        return LMCache("gqa8", (c.k, c.v, c.k_scale, c.v_scale))
    return LMCache("gqa", (c.k, c.v))


def abstract_cache(cfg: TransformerConfig, batch: int, max_seq: int
                   ) -> LMCache:
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq))


def prefill(cfg: TransformerConfig, params: Params, tokens: jnp.ndarray,
            max_seq: Optional[int] = None, *, unroll: bool = False
            ) -> Tuple[jnp.ndarray, LMCache]:
    """Process the prompt; return last-position logits + a cache of
    length max_seq (default: prompt length)."""
    b, s = tokens.shape
    max_seq = max_seq or s
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0

    def run_layer(lp, x, dense_ffn):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        if cfg.attn_type == "mla":
            m = cfg.mla
            q_nope, q_rope, ckv, k_rope = attn._mla_qkv(lp["attn"], h, cfg,
                                                        positions)
            a = attn.mla_forward(lp["attn"], h, cfg, positions)
            kv_out = (_pad_seq(ckv, max_seq),
                      _pad_seq(k_rope[:, :, 0, :], max_seq))
        else:
            a = attn.gqa_forward(lp["attn"], h, cfg, positions)
            hd, kv = cfg.head_dim(), cfg.n_kv_heads
            k = dense(lp["attn"]["wk"], h).reshape(b, s, kv, hd)
            v = dense(lp["attn"]["wv"], h).reshape(b, s, kv, hd)
            k = attn.apply_rope(k, positions, cfg.rope_theta)
            if cfg.kv_cache_dtype == "int8":
                kq, ks_ = attn.quantize_kv(k)
                vq, vs_ = attn.quantize_kv(v)
                kv_out = (_pad_seq(kq, max_seq), _pad_seq(vq, max_seq),
                          _pad_seq(ks_, max_seq), _pad_seq(vs_, max_seq))
            else:
                kv_out = (_pad_seq(k.astype(jnp.bfloat16), max_seq),
                          _pad_seq(v.astype(jnp.bfloat16), max_seq))
        x = x + a
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if "moe" in lp and not dense_ffn:
            f, _ = moe_lib.moe_forward(lp["moe"], h2, cfg)
        else:
            f = mlp(lp["mlp"], h2, cfg.mlp_type)
        return x + f, kv_out

    dense_caches = []
    for i in range(n_dense):
        x, kv_out = run_layer(params[f"dense_layer_{i}"], x, True)
        dense_caches.append(kv_out)

    def scan_fn(x, lp):
        x, kv_out = run_layer(lp, x, False)
        return x, kv_out

    x, scan_caches = jax.lax.scan(scan_fn, x, params["layers"],
                                  unroll=unroll)
    caches = scan_caches
    if dense_caches:
        stacked_dense = jax.tree.map(lambda *xs: jnp.stack(xs),
                                     *dense_caches) \
            if len(dense_caches) > 1 else \
            jax.tree.map(lambda a: a[None], dense_caches[0])
        caches = jax.tree.map(lambda d, sc: jnp.concatenate([d, sc], 0),
                              stacked_dense, scan_caches)
    x = rmsnorm(params["ln_f"], x[:, -1:, :], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T
    else:
        logits = dense(params["out"], x, dtype=jnp.float32)
    kind = ("mla" if cfg.attn_type == "mla"
            else ("gqa8" if cfg.kv_cache_dtype == "int8" else "gqa"))
    return logits[:, 0], LMCache(kind, tuple(caches))


def _pad_seq(x: jnp.ndarray, max_seq: int) -> jnp.ndarray:
    s = x.shape[1]
    if s == max_seq:
        return x
    pad = [(0, 0), (0, max_seq - s)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)


def decode_step(cfg: TransformerConfig, params: Params, cache: LMCache,
                token: jnp.ndarray, pos: jnp.ndarray, *,
                unroll: bool = False) -> Tuple[jnp.ndarray, LMCache]:
    """token (B,1) int32, pos () int32 -> (logits (B,V), new cache)."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0).astype(jnp.bfloat16)
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0

    def run_layer(lp, x, layer_cache, dense_ffn):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        if cfg.attn_type == "mla":
            a, new_cache = attn.mla_decode(lp["attn"], h, cfg, layer_cache,
                                           pos)
        else:
            a, new_cache = attn.gqa_decode(lp["attn"], h, cfg,
                                           _with_scales(layer_cache), pos)
            new_cache = tuple(c for c in new_cache if c is not None)
        x = x + a
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if "moe" in lp and not dense_ffn:
            f, _ = moe_lib.moe_forward(lp["moe"], h2, cfg)
        else:
            f = mlp(lp["mlp"], h2, cfg.mlp_type)
        return x + f, new_cache

    data = cache.data
    new_data = []
    if n_dense:
        head = tuple(a[:n_dense] for a in data)
        tail = tuple(a[n_dense:] for a in data)
        for i in range(n_dense):
            lc = tuple(a[i] for a in head)
            x, nc = run_layer(params[f"dense_layer_{i}"], x, lc, True)
            new_data.append(nc)
    else:
        tail = data

    def scan_fn(x, inp):
        lp, lc = inp
        x, nc = run_layer(lp, x, lc, False)
        return x, nc

    x, scan_out = jax.lax.scan(scan_fn, x, (params["layers"], tail),
                               unroll=unroll)
    if new_data:
        dense_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *new_data) \
            if len(new_data) > 1 else \
            jax.tree.map(lambda a: a[None], new_data[0])
        merged = tuple(jnp.concatenate([d, s_], 0)
                       for d, s_ in zip(dense_stack, scan_out))
    else:
        merged = tuple(scan_out)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x.astype(jnp.float32) @ params["embed"].T
    else:
        logits = dense(params["out"], x, dtype=jnp.float32)
    return logits[:, 0], LMCache(cache.kind, merged)


def _with_scales(layer_cache):
    if len(layer_cache) == 4:
        return layer_cache
    k, v = layer_cache
    return (k, v, None, None)
