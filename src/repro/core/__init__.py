"""The paper's contribution: adaptive early-exit A-kNN for dense retrieval."""
from repro.core.ivf import (DeltaView, IVFIndex, SearchResult,
                            abstract_index, brute_force, build_index,
                            extract_features, min_probes_labels,
                            probe_trace, search, validate_alignment)
from repro.core import metrics, policies
