"""Early-exit policies (paper §2) as jit-composable state machines.

One :class:`Policy` pytree configures the adaptive search:

  fixed(N)                     A-kNN_95 baseline — no early exit
  patience(delta, phi)         the paper's unsupervised heuristic
  regression(reg)              REG  [Li et al., SIGMOD'20]  (groups 1-3)
  regression(reg, +int)        REG+int (adds stability features)
  classifier(clf)              Exit/Continue at tau, survivors run to N
  cascade(clf, patience|reg)   paper §2 "Cascade Approach"

Static layout flags live in pytree aux-data; thresholds and tree arrays
are leaves so one compiled search serves retuned policies.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import FeatureExtras, feature_matrix
from repro.trees.jax_infer import TreeEnsemble, predict_margin


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Policy:
    # --- static (aux) ---
    k: int = 100
    n_probe: int = 80
    tau: int = 10
    min_probes: int = 1
    use_patience: bool = False
    use_reg: bool = False
    reg_with_intersections: bool = False
    use_classifier: bool = False
    name: str = "fixed"
    # --- leaves ---
    delta: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.asarray(7, jnp.int32))
    phi: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.asarray(95.0, jnp.float32))
    clf_threshold: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.asarray(0.5, jnp.float32))
    reg: Optional[TreeEnsemble] = None
    clf: Optional[TreeEnsemble] = None

    def tree_flatten(self):
        leaves = (self.delta, self.phi, self.clf_threshold, self.reg, self.clf)
        aux = (self.k, self.n_probe, self.tau, self.min_probes,
               self.use_patience, self.use_reg, self.reg_with_intersections,
               self.use_classifier, self.name)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        (k, n_probe, tau, min_probes, up, ur, ri, uc, name) = aux
        delta, phi, clf_threshold, reg, clf = leaves
        return cls(k=k, n_probe=n_probe, tau=tau, min_probes=min_probes,
                   use_patience=up, use_reg=ur, reg_with_intersections=ri,
                   use_classifier=uc, name=name, delta=delta, phi=phi,
                   clf_threshold=clf_threshold, reg=reg, clf=clf)


# -- constructors -----------------------------------------------------------

def fixed(n_probe: int, k: int = 100, tau: int = 10) -> Policy:
    return Policy(k=k, n_probe=n_probe, tau=tau, name=f"aknn{n_probe}")


def patience(n_probe: int, delta: int, phi: float = 95.0, k: int = 100,
             tau: int = 10, min_probes: int = 1) -> Policy:
    return Policy(k=k, n_probe=n_probe, tau=tau, use_patience=True,
                  min_probes=min_probes, delta=jnp.asarray(delta, jnp.int32),
                  phi=jnp.asarray(phi, jnp.float32),
                  name=f"patience{delta}")


def regression(n_probe: int, reg: TreeEnsemble, *, with_intersections: bool,
               k: int = 100, tau: int = 10) -> Policy:
    return Policy(k=k, n_probe=n_probe, tau=tau, use_reg=True,
                  reg_with_intersections=with_intersections, reg=reg,
                  min_probes=tau,
                  name="reg+int" if with_intersections else "reg")


def classifier(n_probe: int, clf: TreeEnsemble, *, threshold: float = 0.5,
               k: int = 100, tau: int = 10) -> Policy:
    return Policy(k=k, n_probe=n_probe, tau=tau, use_classifier=True,
                  clf=clf, min_probes=tau,
                  clf_threshold=jnp.asarray(threshold, jnp.float32),
                  name="classifier")


def cascade_patience(n_probe: int, clf: TreeEnsemble, delta: int,
                     phi: float = 95.0, *, threshold: float = 0.5,
                     k: int = 100, tau: int = 10) -> Policy:
    return Policy(k=k, n_probe=n_probe, tau=tau, use_classifier=True,
                  use_patience=True, clf=clf, min_probes=tau,
                  delta=jnp.asarray(delta, jnp.int32),
                  phi=jnp.asarray(phi, jnp.float32),
                  clf_threshold=jnp.asarray(threshold, jnp.float32),
                  name=f"cascade+patience{delta}")


def cascade_regression(n_probe: int, clf: TreeEnsemble, reg: TreeEnsemble,
                       *, threshold: float = 0.5, k: int = 100,
                       tau: int = 10) -> Policy:
    return Policy(k=k, n_probe=n_probe, tau=tau, use_classifier=True,
                  use_reg=True, reg_with_intersections=True, clf=clf,
                  reg=reg, min_probes=tau,
                  clf_threshold=jnp.asarray(threshold, jnp.float32),
                  name="cascade+reg")


# -- deadline degradation ladder -------------------------------------------
#
# Early exit is the natural graceful-degradation actuator: each rung
# trades a little effectiveness for bounded latency instead of blowing
# the deadline.  Rungs are ordered by severity; the scheduler walks up
# as a lane's remaining budget (measured in estimated wave costs)
# shrinks:
#
#   0 NONE     full patience, full probe budget
#   1 TIGHTEN  patience delta clamped to ``tight_delta`` (exit sooner)
#   2 CAP      remaining probes capped to what the budget still affords
#   3 FORCE    lane force-exited now with its partial top-k
#
# A 4th, outside the lane state machine: when even a *fresh* query
# cannot meet the deadline (estimated wave cost exceeds it), admissions
# are shed ("shed" reason) instead of being enqueued to certain death.

RUNG_NONE, RUNG_TIGHTEN, RUNG_CAP, RUNG_FORCE = 0, 1, 2, 3

#: reason strings recorded in ``ServeReport.degraded``, by severity
DEGRADE_REASONS = ("tightened_patience", "capped_probes", "forced_exit",
                   "shed")


@dataclasses.dataclass(frozen=True)
class DegradationLadder:
    """Maps a lane's remaining deadline budget to a degradation rung.

    Thresholds are in units of the scheduler's current per-wave cost
    estimate, so the ladder adapts to load: under a latency spike every
    lane's effective budget shrinks and the rungs fire earlier.
    """
    tighten_at: float = 3.0      # remaining < 3 wave costs -> rung 1
    cap_at: float = 1.5          # remaining < 1.5 wave costs -> rung 2
    force_at: float = 0.0        # remaining <= 0 wave costs -> rung 3
    tight_delta: int = 1         # patience delta while on rung >= 1
    rebuild_pause_at: float = 4.0  # pause background rebuild ticks when
    #                                any lane's remaining budget drops
    #                                below this many wave costs

    def __post_init__(self):
        if not (self.force_at <= self.cap_at <= self.tighten_at):
            raise ValueError(
                f"ladder thresholds must be ordered force_at <= cap_at "
                f"<= tighten_at, got {self.force_at}/{self.cap_at}/"
                f"{self.tighten_at}")

    def rungs(self, remaining_ms: np.ndarray,
              wave_cost_ms: float) -> np.ndarray:
        """(W,) remaining budget -> (W,) int rung (vectorised)."""
        r = np.asarray(remaining_ms, np.float64) / max(wave_cost_ms, 1e-9)
        out = np.full(r.shape, RUNG_NONE, np.int8)
        out[r < self.tighten_at] = RUNG_TIGHTEN
        out[r < self.cap_at] = RUNG_CAP
        out[r <= self.force_at] = RUNG_FORCE
        return out

    def throttle_rebuild(self, remaining_ms: np.ndarray,
                         wave_cost_ms: float) -> bool:
        """Should background rebuild work pause this wave?

        True when ANY active lane's remaining deadline budget is below
        ``rebuild_pause_at`` wave costs: a retrain/re-layout stage
        stalls the serving thread for roughly a wave's worth of work,
        so it must not run while a lane is close enough to its
        deadline that the stall would push it onto a degradation rung.
        An empty ``remaining_ms`` (no active lanes, or no deadline)
        never throttles.
        """
        r = np.asarray(remaining_ms, np.float64)
        if r.size == 0:
            return False
        return bool((r / max(wave_cost_ms, 1e-9)
                     < self.rebuild_pause_at).any())


# -- step -------------------------------------------------------------------


class PolicyDecision(NamedTuple):
    exit: jnp.ndarray          # (B,) bool — policy wants to stop this query
    patience_ctr: jnp.ndarray  # (B,) int32
    target: jnp.ndarray        # (B,) int32 probe budget


def policy_step(policy: Policy, *, h: jnp.ndarray, phi: jnp.ndarray,
                patience_ctr: jnp.ndarray, target: jnp.ndarray,
                extras: FeatureExtras) -> PolicyDecision:
    """Evaluate exit logic after probe ``h`` (0-based; probes done = h+1)."""
    b = phi.shape[0]
    probes_done = h + 1

    # ---- patience ----
    if policy.use_patience:
        ctr = jnp.where((h >= 1) & (phi >= policy.phi), patience_ctr + 1, 0)
        exit_pat = ctr >= policy.delta
    else:
        ctr = patience_ctr
        exit_pat = jnp.zeros((b,), bool)

    # ---- learned stages fire once, when probes_done == tau ----
    exit_clf = jnp.zeros((b,), bool)
    if policy.use_classifier or policy.use_reg:
        def at_tau(operand):
            extras_, target_ = operand
            exit_c = jnp.zeros((b,), bool)
            tgt = target_
            if policy.use_classifier:
                fm = feature_matrix(extras_, with_intersections=True)
                p_exit = jax.nn.sigmoid(predict_margin(policy.clf, fm))
                exit_c = p_exit >= policy.clf_threshold
            if policy.use_reg:
                fm = feature_matrix(
                    extras_,
                    with_intersections=policy.reg_with_intersections)
                pred = predict_margin(policy.reg, fm)
                tgt = jnp.clip(jnp.round(pred), policy.tau,
                               policy.n_probe).astype(jnp.int32)
            return exit_c, tgt

        def skip(operand):
            _, target_ = operand
            return jnp.zeros((b,), bool), target_

        exit_clf, target = jax.lax.cond(
            probes_done == policy.tau, at_tau, skip, (extras, target))

    exit_tgt = probes_done >= target if policy.use_reg else \
        jnp.zeros((b,), bool)
    return PolicyDecision(exit_pat | exit_clf | exit_tgt, ctr, target)
