"""Wave-scheduled serving: turning per-query early exit into TPU
throughput (beyond-paper, DESIGN §2).

On a SIMD batch, an exited query's lane otherwise idles until the whole
batch finishes. The wave scheduler advances lane states by fixed probe
chunks, then *compacts*: exited lanes are refilled with queued queries.
Effective cost per query approaches the paper's C̄ instead of max-C of
the batch.

Lane state is a pytree of (W, ...) arrays; admission/compaction are
gather/scatters on device; the host loop only moves query ids.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import (DeltaView, IVFIndex, _merge_topk, _probe_tiles,
                            _scrub_dead, intersection_pct,
                            validate_alignment)
from repro.core.policies import (RUNG_CAP, RUNG_FORCE, RUNG_NONE,
                                 RUNG_TIGHTEN, DegradationLadder)


class LaneState(NamedTuple):
    qvec: jnp.ndarray         # (W, d) admitted query vectors
    cluster_rank: jnp.ndarray # (W, N)
    h: jnp.ndarray            # (W,) per-lane next probe rank
    topk_scores: jnp.ndarray  # (W, k)
    topk_ids: jnp.ndarray     # (W, k)
    patience: jnp.ndarray     # (W,)
    active: jnp.ndarray       # (W,) bool — lane holds a live query
    qid: jnp.ndarray          # (W,) int32 external id, -1 empty


def _empty_state(w: int, d: int, n: int, k: int) -> LaneState:
    return LaneState(
        qvec=jnp.zeros((w, d), jnp.float32),
        cluster_rank=jnp.zeros((w, n), jnp.int32),
        h=jnp.zeros((w,), jnp.int32),
        topk_scores=jnp.full((w, k), -jnp.inf, jnp.float32),
        topk_ids=jnp.full((w, k), -1, jnp.int32),
        patience=jnp.zeros((w, ), jnp.int32),
        active=jnp.zeros((w,), bool),
        qid=jnp.full((w,), -1, jnp.int32))


@functools.partial(jax.jit, static_argnames=("n_probe",))
def _admit(state: LaneState, centroids: jnp.ndarray, new_q: jnp.ndarray,
           new_qid: jnp.ndarray, n_probe: int) -> LaneState:
    """Fill empty lanes with up to len(new_q) queries (vectorised)."""
    w = state.active.shape[0]
    free = ~state.active                                  # (W,)
    # slot j of new_q goes to the j-th free lane
    free_rank = jnp.cumsum(free) - 1                      # rank among free
    take = free & (free_rank < new_q.shape[0])
    src = jnp.clip(free_rank, 0, new_q.shape[0] - 1)
    csims = new_q @ centroids.T
    _, rank = jax.lax.top_k(csims, n_probe)
    def fill(old, new_full, extra_dims):
        newv = jnp.take(new_full, src, axis=0)
        m = take.reshape((-1,) + (1,) * extra_dims)
        return jnp.where(m, newv, old)
    return LaneState(
        qvec=fill(state.qvec, new_q, 1),
        cluster_rank=fill(state.cluster_rank, rank.astype(jnp.int32), 1),
        h=jnp.where(take, 0, state.h),
        topk_scores=jnp.where(take[:, None], -jnp.inf, state.topk_scores),
        topk_ids=jnp.where(take[:, None], -1, state.topk_ids),
        patience=jnp.where(take, 0, state.patience),
        active=state.active | take,
        qid=jnp.where(take, jnp.take(new_qid, src), state.qid))


@functools.partial(jax.jit,
                   static_argnames=("chunk", "k", "n_probe", "use_fused"))
def _advance(index: IVFIndex, state: LaneState,
             dview: Optional[DeltaView] = None,
             dead: Optional[jnp.ndarray] = None, *,
             lane_delta: jnp.ndarray, lane_cap: jnp.ndarray, chunk: int,
             k: int, n_probe: int, phi: float,
             use_fused: bool = True) -> LaneState:
    """Advance every active lane by up to ``chunk`` probes.

    ``lane_delta``/``lane_cap`` are per-lane (W,) exit knobs: the
    patience threshold and the probe budget.  Without a deadline both
    are constant (the scheduler's ``delta``/``n_probe``); under
    deadline pressure the degradation ladder lowers them per lane, so
    a struggling lane exits earlier while its neighbours run the full
    policy.  Exit granularity stays per-probe either way.

    The fused path issues ONE ``ivf_scan_merge`` dispatch for the whole
    chunk — lanes stop materializing ``(W, list_pad, d)`` doc gathers,
    raw scores stay in VMEM, and the per-probe patience signal comes
    from the kernel's new-entry counts.  Exit granularity is unchanged:
    lane state is rolled forward slot by slot from the kernel's
    per-probe top-k snapshots, so mid-chunk exits land on the exact
    probe they would have on the unfused path.

    ``dview``/``dead`` (live-mutation overlay, ``repro.index``): delta
    entries are brute-force scored once per wave and merged into a
    lane's running top-k at the probe of their assigned cluster (same
    bit-identity rule as ``core.search``); ``dead`` is the cumulative
    tombstone lookup, scrubbing running top-k entries that were deleted
    after they were merged — required for mid-flight lanes that span an
    index version swap.
    """

    if dead is not None:
        # scrub once per wave: a lane's carry may predate a deletion
        ts0, ti0 = _scrub_dead(state.topk_scores, state.topk_ids, dead)
        state = state._replace(topk_scores=ts0, topk_ids=ti0)

    if dview is not None:
        # burn tombstoned buffer entries to id -1 up front (cheap
        # elementwise op): both kernel paths then mask them exactly
        # like empty slots, with no per-slot re-merge
        d_ids_eff = dview.ids
        if dead is not None:
            gone = jnp.take(dead, jnp.clip(dview.ids, 0,
                                           dead.shape[0] - 1)) \
                & (dview.ids >= 0)
            d_ids_eff = jnp.where(gone, -1, dview.ids)
        if not use_fused:
            from repro.kernels import ops as kops
            d_sc = kops.delta_scan(state.qvec, dview.vecs)  # (W, cap)
            d_valid = (d_ids_eff >= 0)[None, :]
            d_ids = jnp.broadcast_to(d_ids_eff[None, :], d_sc.shape)

    def delta_cands(gate):
        return (jnp.where(gate, d_sc, -jnp.inf),
                jnp.where(gate, d_ids, -1))

    def slot(st: LaneState, ms, mi, phi_v) -> LaneState:
        act = st.active[:, None]
        ts = jnp.where(act, ms, st.topk_scores)
        ti = jnp.where(act, mi, st.topk_ids)
        ctr = jnp.where(st.active & (st.h >= 1) & (phi_v >= phi),
                        st.patience + 1, 0)
        h = jnp.where(st.active, st.h + 1, st.h)
        exited = st.active & ((ctr >= lane_delta) | (h >= lane_cap))
        return LaneState(st.qvec, st.cluster_rank, h, ts, ti, ctr,
                         st.active & ~exited, st.qid)

    if use_fused:
        from repro.kernels import ops as kops
        rel = jnp.arange(chunk, dtype=jnp.int32)[None, :]
        idx = jnp.clip(state.h[:, None] + rel, 0, n_probe - 1)
        cids = jnp.take_along_axis(state.cluster_rank, idx, axis=1)
        offs = jnp.take(index.cluster_offsets, cids)
        # inactive lanes and slots past the probe budget merge nothing
        slot_ok = ((state.h[:, None] + rel) < n_probe) \
            & state.active[:, None]
        sizes = jnp.where(slot_ok, jnp.take(index.cluster_sizes, cids), 0)
        if dview is not None:
            # delta buffer rides the kernel as a second prefetch
            # stream, gated per slot on the assigned cluster id
            # (see core.ivf._search): still ONE dispatch per chunk
            gates = jnp.where(slot_ok, cids, -2)
            snap_s, snap_i, cnts = kops.ivf_scan_merge(
                state.qvec, index.docs, index.doc_ids, offs, sizes,
                state.topk_scores, state.topk_ids, dview.vecs,
                d_ids_eff, dview.assign, gates, k=k,
                list_pad=index.list_pad, chunk=chunk)
        else:
            snap_s, snap_i, cnts = kops.ivf_scan_merge(
                state.qvec, index.docs, index.doc_ids, offs, sizes,
                state.topk_scores, state.topk_ids, k=k,
                list_pad=index.list_pad, chunk=chunk)
        st = state
        for t in range(chunk):
            phi_v = 100.0 * (k - cnts[:, t]).astype(jnp.float32) / k
            st = slot(st, snap_s[:, t], snap_i[:, t], phi_v)
        return st

    def body(_, st: LaneState) -> LaneState:
        hv = jnp.minimum(st.h, n_probe - 1)
        cids = jnp.take_along_axis(st.cluster_rank, hv[:, None], 1)[:, 0]
        tiles, ids, mask = _probe_tiles(index, cids)
        sc = jnp.einsum("bld,bd->bl", tiles, st.qvec)
        sc = jnp.where(mask, sc, -jnp.inf)
        if dview is not None:
            gate = d_valid & (dview.assign[None, :] == cids[:, None])
            e_s, e_i = delta_cands(gate)
            sc = jnp.concatenate([sc, e_s], axis=1)
            ids = jnp.concatenate([ids, e_i], axis=1)
        ms, mi = _merge_topk(st.topk_scores, st.topk_ids, sc, ids, k)
        ti = jnp.where(st.active[:, None], mi, st.topk_ids)
        return slot(st, ms, mi, intersection_pct(st.topk_ids, ti))

    return jax.lax.fori_loop(0, chunk, body, state)


#: ordering of degradation reasons — a stronger rung overwrites a weaker
_REASON_RANK = {"tightened_patience": 1, "capped_probes": 2,
                "forced_exit": 3, "shed": 4}


@dataclasses.dataclass
class ServeReport:
    results: Dict[int, np.ndarray]
    probes: Dict[int, int]
    waves: int
    occupancy: float            # mean fraction of busy lanes per wave
    lane_steps: int             # total lane-probe slots spent
    # -- deadline/degradation accounting (empty when deadline_ms unset) --
    degraded: Dict[int, str] = dataclasses.field(default_factory=dict)
    latency_ms: Dict[int, float] = dataclasses.field(default_factory=dict)
    deadline_ms: Optional[float] = None
    wave_cost_ms: float = 0.0   # final EMA of per-wave cost
    # -- background rebuild accounting (zero without a rebuilder) --
    epoch_swaps: int = 0        # higher-epoch versions adopted (drained)
    drain_waves: int = 0        # waves spent draining before a swap
    rebuild_ticks: int = 0      # rebuild stages run between waves
    rebuild_throttled: int = 0  # ticks skipped under deadline pressure

    @property
    def degraded_fraction(self) -> float:
        return len(self.degraded) / max(len(self.results), 1)

    def shed_ids(self) -> List[int]:
        return [q for q, r in self.degraded.items() if r == "shed"]


class WaveScheduler:
    """Throughput-oriented serving loop over the adaptive search.

    ``registry`` (optional, ``repro.index.IndexRegistry``): between
    waves the scheduler re-reads ``registry.current()`` and advances
    against that version's (index, delta view, tombstones) — an atomic
    swap point.  Mid-flight lanes stay correct across swaps: probes
    already taken saw buffered docs through the delta overlay, probes
    still to come see them inside the merged lists (centroids are fixed
    under mutation, so each lane's cluster_rank stays valid), and the
    per-wave tombstone scrub evicts results deleted after they were
    merged.

    **Epoch-fenced swaps** (background re-clustering,
    ``repro.index.rebuild``): a version whose ``epoch`` is HIGHER than
    the one lanes are probing carries re-trained centroids, so every
    in-flight ``cluster_rank`` would be meaningless against it.  The
    scheduler therefore *drains*: it pins the old version, stops
    admitting, finishes in-flight lanes against the pinned epoch
    (their results are correct for the corpus they were admitted
    under — mutation catch-up means no document is missing), and
    adopts the new epoch only once no lane is active.  Same-epoch
    version swaps (``merge_delta``) keep the old wave-granular
    behavior.

    ``rebuilder`` (optional, ``repro.index.rebuild.Rebuilder``): when
    armed, the scheduler runs ONE rebuild pipeline stage between waves
    — unless the degradation ladder's ``throttle_rebuild`` says a lane
    is too close to its deadline to absorb the stall.
    """

    def __init__(self, index: IVFIndex, *, wave_size: int = 64,
                 chunk: int = 8, k: int = 100, n_probe: int = 80,
                 delta: int = 7, phi: float = 95.0,
                 use_fused: bool = True, registry=None,
                 deadline_ms: Optional[float] = None,
                 ladder: Optional[DegradationLadder] = None,
                 clock: Optional[Callable[[], float]] = None,
                 rebuilder=None):
        """``deadline_ms``: per-query latency budget, counted from lane
        admission.  When set, the scheduler walks the
        :class:`repro.core.policies.DegradationLadder` instead of
        blowing the budget: tighten patience -> cap remaining probes ->
        force-exit with the partial top-k -> shed admissions.  Every
        affected query carries a reason in ``ServeReport.degraded``.

        ``clock``: ms-resolution monotonic clock (injectable for
        deterministic tests and the chaos harness); defaults to
        ``time.monotonic() * 1000``.
        """
        if use_fused:
            validate_alignment(index)
        self.index = index
        self.w = wave_size
        self.chunk = chunk
        self.k = k
        self.n = min(n_probe, index.n_clusters)
        self.delta = delta
        self.phi = phi
        self.use_fused = use_fused
        self.registry = registry
        self.deadline_ms = deadline_ms
        self.ladder = ladder or DegradationLadder()
        self._now = clock or (lambda: time.monotonic() * 1000.0)
        self.rebuilder = rebuilder
        self._pinned = None        # version lanes are probing against

    def _refresh_pin(self, active_any: bool) -> Tuple[bool, bool]:
        """Adopt the registry's current version if lanes allow it.

        Same-epoch updates (merge_delta) adopt immediately — the
        wave-granular swap that mid-flight lanes tolerate.  A
        higher-epoch version (rebuild: new centroids) only lands once
        no lane is active; until then the scheduler reports *drain*
        and the caller stops admitting.  Returns ``(draining,
        swapped)``.
        """
        if self.registry is None:
            return False, False
        cur = self.registry.current()
        if self._pinned is None:
            self._pinned = cur
            return False, False
        cur_epoch = getattr(cur, "epoch", 0)
        pin_epoch = getattr(self._pinned, "epoch", 0)
        if cur_epoch == pin_epoch:
            self._pinned = cur
            return False, False
        if active_any:
            return True, False     # drain: finish lanes on old epoch
        self._pinned = cur
        return False, True

    def _version(self):
        if self.registry is None:
            return self.index, None, None
        ver = self._pinned if self._pinned is not None \
            else self.registry.current()
        return ver.index, ver.delta, ver.dead

    def _centroids(self):
        """Centroids new admissions rank clusters against — must match
        the epoch their lanes will probe."""
        ix, _, _ = self._version()
        return ix.centroids

    @staticmethod
    def _flag(degraded: Dict[int, str], qid: int, reason: str) -> None:
        old = degraded.get(qid)
        if old is None or _REASON_RANK[reason] > _REASON_RANK[old]:
            degraded[qid] = reason

    def serve(self, queries: np.ndarray, *, compact: bool = True,
              on_wave=None) -> ServeReport:
        d = queries.shape[1]
        state = _empty_state(self.w, d, self.n, self.k)
        next_q = 0
        results: Dict[int, np.ndarray] = {}
        probes: Dict[int, int] = {}
        degraded: Dict[int, str] = {}
        latency: Dict[int, float] = {}
        waves = 0
        occ = []
        lane_steps = 0
        nq = queries.shape[0]
        prev_active = np.zeros(self.w, bool)
        prev_state = state
        lane_admit = np.zeros(self.w, np.float64)   # admit timestamp, ms
        full_delta = jnp.full((self.w,), self.delta, jnp.int32)
        full_cap = jnp.full((self.w,), self.n, jnp.int32)
        wave_cost = 0.0                              # EMA of wave ms
        epoch_swaps = drain_waves = 0
        rebuild_ticks = rebuild_throttled = 0
        self._pinned = None if self.registry is None \
            else self.registry.current()
        while True:
            active = np.asarray(state.active)
            qids = np.asarray(state.qid)
            now = self._now()
            # harvest exits: lanes that flipped active->inactive
            for lane in np.nonzero(prev_active & ~active)[0]:
                qid = int(np.asarray(prev_state.qid)[lane])
                results[qid] = np.asarray(state.topk_ids)[lane]
                probes[qid] = int(np.asarray(state.h)[lane])
                latency[qid] = now - lane_admit[lane]
            # -- epoch-fenced version adoption ------------------------------
            draining, swapped = self._refresh_pin(bool(active.any()))
            if swapped:
                epoch_swaps += 1
            if draining:
                drain_waves += 1
            # -- degradation ladder (deadline-budgeted serving) -------------
            lane_delta, lane_cap = full_delta, full_cap
            if self.deadline_ms is not None:
                remaining = self.deadline_ms - (now - lane_admit)
                rungs = self.ladder.rungs(remaining, max(wave_cost, 1e-9))
                rungs = np.where(active, rungs, RUNG_NONE)
                force = active & (rungs == RUNG_FORCE)
                if force.any():
                    h_np = np.asarray(state.h)
                    tid = np.asarray(state.topk_ids)
                    for lane in np.nonzero(force)[0]:
                        qid = int(qids[lane])
                        results[qid] = tid[lane]
                        probes[qid] = int(h_np[lane])
                        latency[qid] = now - lane_admit[lane]
                        self._flag(degraded, qid, "forced_exit")
                    active = active & ~force
                    state = state._replace(active=jnp.asarray(active))
                for lane in np.nonzero(active
                                       & (rungs >= RUNG_TIGHTEN))[0]:
                    self._flag(degraded, int(qids[lane]),
                               "capped_probes" if rungs[lane] >= RUNG_CAP
                               else "tightened_patience")
                if (rungs > RUNG_NONE).any():
                    h_np = np.asarray(state.h)
                    afford = np.floor(
                        np.maximum(remaining, 0.0)
                        / max(wave_cost, 1e-9)).astype(np.int64) \
                        * self.chunk
                    cap_np = np.where(rungs >= RUNG_CAP, h_np + afford,
                                      self.n)
                    cap_np = np.minimum(cap_np, self.n).astype(np.int32)
                    tight = min(self.ladder.tight_delta, self.delta)
                    delta_np = np.where(rungs >= RUNG_TIGHTEN, tight,
                                        self.delta).astype(np.int32)
                    lane_delta = jnp.asarray(delta_np)
                    lane_cap = jnp.asarray(cap_np)
            # -- admission (with overload shedding) -------------------------
            if (compact or not active.any()) and not draining:
                if next_q < nq and (~active).any():
                    room = int((~active).sum())
                    if self.deadline_ms is not None \
                            and wave_cost > self.deadline_ms:
                        # even a fresh query cannot meet the deadline:
                        # shed instead of admitting to certain death
                        for qid in range(next_q,
                                         min(nq, next_q + room)):
                            results[qid] = np.full(self.k, -1, np.int32)
                            probes[qid] = 0
                            latency[qid] = 0.0
                            self._flag(degraded, qid, "shed")
                        next_q = min(nq, next_q + room)
                    else:
                        batch = queries[next_q: next_q + room]
                        ids = np.arange(next_q,
                                        next_q + batch.shape[0],
                                        dtype=np.int32)
                        before = active
                        state = _admit(state, self._centroids(),
                                       jnp.asarray(batch),
                                       jnp.asarray(ids), self.n)
                        next_q += batch.shape[0]
                        newly = np.asarray(state.active) & ~before
                        lane_admit[newly] = now
            active = np.asarray(state.active)
            if not active.any() and next_q >= nq:
                break
            occ.append(active.mean())
            lane_steps += self.w * self.chunk
            prev_active = active
            prev_state = state
            index, dview, dead = self._version()
            state = _advance(index, state, dview, dead,
                             lane_delta=lane_delta, lane_cap=lane_cap,
                             chunk=self.chunk, k=self.k, n_probe=self.n,
                             phi=self.phi, use_fused=self.use_fused)
            waves += 1
            if on_wave is not None:
                on_wave(waves)
            sample = self._now() - now
            wave_cost = sample if waves == 1 \
                else 0.5 * wave_cost + 0.5 * sample
            # -- background rebuild tick (throttled under pressure) ---------
            # after the wave-cost sample so the stall never inflates
            # the EMA the ladder budgets against
            if self.rebuilder is not None and self.rebuilder.active:
                throttle = False
                if self.deadline_ms is not None:
                    act_now = np.asarray(state.active)
                    rem = (self.deadline_ms
                           - (self._now() - lane_admit))[act_now]
                    throttle = self.ladder.throttle_rebuild(
                        rem, max(wave_cost, 1e-9))
                if throttle:
                    rebuild_throttled += 1
                else:
                    self.rebuilder.tick()
                    rebuild_ticks += 1
        return ServeReport(results, probes, waves,
                           float(np.mean(occ)) if occ else 0.0,
                           lane_steps, degraded=degraded,
                           latency_ms=latency,
                           deadline_ms=self.deadline_ms,
                           wave_cost_ms=wave_cost,
                           epoch_swaps=epoch_swaps,
                           drain_waves=drain_waves,
                           rebuild_ticks=rebuild_ticks,
                           rebuild_throttled=rebuild_throttled)
