"""IVF two-level index + batched adaptive (early-exit) A-kNN search.

TPU-native layout (DESIGN §2): document embeddings are stored
cluster-major and every inverted list is <= ``list_pad`` rows (oversized
k-means clusters are 2-means split at build time), so one probe ==
streaming one contiguous ``(list_pad, d)`` tile per query + one MXU
scoring matmul + one vectorised top-k merge. Early exit is a per-query
*active mask* inside a ``lax.while_loop``; the loop terminates when all
queries exited or N probes were spent.

The adaptive policies (Patience / REG / Classifier / Cascade) are
described in the paper §2 and implemented in ``repro.core.policies``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kmeans as km
from repro.core.policies import Policy, PolicyDecision, policy_step
from repro.core.features import FeatureExtras


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class IVFIndex:
    """Cluster-major IVF index (all arrays device-ready)."""

    centroids: jnp.ndarray        # (C, d) f32
    docs: jnp.ndarray             # (n_pad, d) cluster-major, zero padded tail
    doc_ids: jnp.ndarray          # (n_pad,) int32, -1 on padding
    cluster_offsets: jnp.ndarray  # (C,) int32 row offset of each list
    cluster_sizes: jnp.ndarray    # (C,) int32
    list_pad: int                 # static: tile rows streamed per probe

    def tree_flatten(self):
        return ((self.centroids, self.docs, self.doc_ids,
                 self.cluster_offsets, self.cluster_sizes), self.list_pad)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux)

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]


class DeltaView(NamedTuple):
    """Device view of the live-mutation delta buffer (``repro.index``).

    Fixed-capacity arrays; empty (or tombstoned) slots carry id -1.
    ``assign`` is the nearest-centroid cluster each buffered vector
    will be merged into, which gates *when* it becomes visible to a
    query: a delta vector is merged into the running top-k at the
    probe of its assigned cluster, so results are bit-identical to a
    rebuilt index holding the same net corpus for every exit policy.
    """
    vecs: jnp.ndarray     # (cap, d) f32
    ids: jnp.ndarray      # (cap,) int32 external doc ids, -1 empty
    assign: jnp.ndarray   # (cap,) int32 assigned cluster, -1 empty


def validate_alignment(index: IVFIndex, *, blk_l: int = 64) -> None:
    """Eagerly enforce the fused-kernel layout contract.

    The Pallas scan kernels stream ``(blk_l, d)`` tiles addressed by
    scalar-prefetched *block* offsets, so every inverted-list offset
    must be a ``blk_l`` multiple and ``list_pad`` must be divisible by
    ``blk_l`` — otherwise the kernel would silently score the wrong
    rows.  Raises ``ValueError`` with a pointer at ``build_index``
    instead.  No-op for abstract (ShapeDtypeStruct) indexes.
    """
    if blk_l <= 0:
        raise ValueError(f"blk_l must be positive, got {blk_l}")
    if index.list_pad % blk_l:
        raise ValueError(
            f"list_pad={index.list_pad} is not a multiple of blk_l="
            f"{blk_l}; rebuild with build_index(list_pad=<{blk_l}"
            f"-multiple>) or pass a compatible blk_l")
    offs = index.cluster_offsets
    if not hasattr(offs, "__array__"):          # abstract dry-run index
        return
    offs = np.asarray(offs)
    bad = np.nonzero(offs % blk_l)[0]
    if bad.size:
        raise ValueError(
            f"{bad.size} inverted-list offsets are not blk_l={blk_l} "
            f"aligned (first bad cluster {int(bad[0])}, offset "
            f"{int(offs[bad[0]])}); the fused scan kernel would stream "
            f"misaligned tiles and compute garbage. Rebuild the index "
            f"with build_index(align={blk_l}) (or a multiple).")


def build_index(docs: np.ndarray, n_clusters: int, *, list_pad: int = 256,
                n_iters: int = 10, seed: int = 0,
                align: int = 64) -> IVFIndex:
    """k-means -> oversize split -> cluster-major re-layout.

    ``align``: every inverted list starts at a multiple of ``align``
    rows (gap rows id=-1), so the Pallas scan kernel can stream
    (align, d) tiles with block-aligned scalar-prefetch offsets.
    """
    if align <= 0:
        raise ValueError(f"align must be positive, got {align}")
    if list_pad % align:
        raise ValueError(
            f"list_pad={list_pad} must be a multiple of align={align} "
            f"so list offsets stay tile-aligned for the scan kernels")
    docs = np.asarray(docs, np.float32)
    centroids, assign = km.kmeans(docs, n_clusters, n_iters=n_iters, seed=seed)
    centroids, assign = km.split_oversized(docs, centroids, assign, list_pad,
                                           seed=seed)
    c = centroids.shape[0]
    d = docs.shape[1]
    sizes = np.bincount(assign, minlength=c).astype(np.int32)
    aligned = ((sizes + align - 1) // align) * align
    offsets = np.zeros(c, np.int32)
    offsets[1:] = np.cumsum(aligned)[:-1].astype(np.int32)
    total = int(aligned.sum()) + list_pad
    sorted_docs = np.zeros((total, d), np.float32)
    sorted_ids = np.full(total, -1, np.int32)
    order = np.argsort(assign, kind="stable")
    row = 0
    pos = 0
    srt = assign[order]
    for cid in range(c):
        sz = int(sizes[cid])
        sel = order[pos: pos + sz]
        sorted_docs[offsets[cid]: offsets[cid] + sz] = docs[sel]
        sorted_ids[offsets[cid]: offsets[cid] + sz] = sel
        pos += sz
    return IVFIndex(jnp.asarray(centroids), jnp.asarray(sorted_docs),
                    jnp.asarray(sorted_ids), jnp.asarray(offsets),
                    jnp.asarray(sizes), list_pad)


def abstract_index(n_docs: int, dim: int, n_clusters: int,
                   list_pad: int) -> IVFIndex:
    """ShapeDtypeStruct stand-in for dry-runs (no allocation)."""
    sd = jax.ShapeDtypeStruct
    return IVFIndex(sd((n_clusters, dim), jnp.float32),
                    sd((n_docs + list_pad, dim), jnp.float32),
                    sd((n_docs + list_pad,), jnp.int32),
                    sd((n_clusters,), jnp.int32),
                    sd((n_clusters,), jnp.int32), list_pad)


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


class SearchState(NamedTuple):
    h: jnp.ndarray                # () int32 — probes done so far
    topk_scores: jnp.ndarray      # (B, k)
    topk_ids: jnp.ndarray         # (B, k)
    rs1_ids: jnp.ndarray          # (B, k) result set after first probe
    phi_hist: jnp.ndarray         # (B, tau-1) consecutive intersections (%)
    phi1_hist: jnp.ndarray        # (B, tau-1) intersection with RS_1 (%)
    centroid_sims: jnp.ndarray    # (B, tau)
    patience_ctr: jnp.ndarray     # (B,) int32
    target: jnp.ndarray           # (B,) int32 probes budget (REG/cascade)
    active: jnp.ndarray           # (B,) bool
    probes: jnp.ndarray           # (B,) int32 probes actually used


class SearchResult(NamedTuple):
    topk_scores: jnp.ndarray
    topk_ids: jnp.ndarray
    probes: jnp.ndarray           # (B,) int32
    phi_hist: jnp.ndarray         # (B, tau-1) — for diagnostics/benchmarks


def intersection_pct(a_ids: jnp.ndarray, b_ids: jnp.ndarray) -> jnp.ndarray:
    """100*|A ∩ B|/k for padded id sets (-1 = empty slot). (B,k)x(B,k)->(B,)"""
    k = a_ids.shape[-1]
    eq = (a_ids[..., :, None] == b_ids[..., None, :]) & (a_ids[..., :, None] >= 0)
    return 100.0 * jnp.sum(eq, axis=(-2, -1)).astype(jnp.float32) / k


def _probe_tiles(index: IVFIndex, cids: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Stream each query's cluster tile: (B,L,d) docs, (B,L) ids, (B,L) mask."""
    lp = index.list_pad
    offs = jnp.take(index.cluster_offsets, cids)
    sizes = jnp.take(index.cluster_sizes, cids)
    tiles = jax.vmap(
        lambda o: jax.lax.dynamic_slice_in_dim(index.docs, o, lp, axis=0))(offs)
    ids = jax.vmap(
        lambda o: jax.lax.dynamic_slice_in_dim(index.doc_ids, o, lp, axis=0))(offs)
    mask = jnp.arange(lp)[None, :] < sizes[:, None]
    ids = jnp.where(mask, ids, -1)
    # stored id -1 inside a list == tombstoned doc: mask it like padding
    return tiles, ids, mask & (ids >= 0)


def _scrub_dead(scores: jnp.ndarray, ids: jnp.ndarray, dead: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mask candidates whose external id is tombstoned.

    ``dead`` is the cumulative (id_capacity,) bool lookup from
    ``repro.index``; needed when a running top-k can carry ids that
    were deleted *after* they were merged (version swaps mid-query)."""
    gone = jnp.take(dead, jnp.clip(ids, 0, dead.shape[0] - 1)) & (ids >= 0)
    return (jnp.where(gone, -jnp.inf, scores), jnp.where(gone, -1, ids))


def _merge_topk(scores: jnp.ndarray, ids: jnp.ndarray, new_scores: jnp.ndarray,
                new_ids: jnp.ndarray, k: int, use_kernel: bool = False,
                dead: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if dead is not None:
        scores, ids = _scrub_dead(scores, ids, dead)
        new_scores, new_ids = _scrub_dead(new_scores, new_ids, dead)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.topk_merge(scores, ids, new_scores, new_ids, k)
    cat_s = jnp.concatenate([scores, new_scores], axis=1)
    cat_i = jnp.concatenate([ids, new_ids], axis=1)
    top_s, idx = jax.lax.top_k(cat_s, k)
    top_i = jnp.take_along_axis(cat_i, idx, axis=1)
    return top_s, top_i


def search(index: IVFIndex, queries: jnp.ndarray, policy: Policy, *,
           delta: Optional[DeltaView] = None,
           use_scan_kernel: bool = False, use_topk_kernel: bool = False,
           use_fused_kernel: bool = False, chunk: int = 1,
           blk_l: int = 64) -> SearchResult:
    """Batched adaptive A-kNN: probe clusters in similarity order with
    per-query early exit.

    ``policy`` is a static (hashable) Policy; tree ensembles used by
    REG/Classifier live in ``policy.reg``/``policy.clf`` as numpy-backed
    constants baked into the jaxpr.

    ``chunk`` probes are advanced per ``while_loop`` iteration (the
    per-probe slots are unrolled in the body), cutting dispatch
    overhead ``chunk``-fold.  The exit policy is still evaluated at
    per-probe granularity from per-probe top-k snapshots, so results
    and probe counts are bit-identical to ``chunk=1`` for every policy.

    ``use_fused_kernel`` routes the whole chunk through the fused
    scan+merge Pallas kernel (``kernels/ivf_scan_merge.py``): one
    dispatch per chunk, raw scores never leave VMEM, and the patience
    signal phi is recovered from the kernel's per-probe new-entry
    counts instead of re-running ``intersection_pct``.

    ``delta`` (live-mutation subsystem, ``repro.index``): a fixed-
    capacity buffer of recently added vectors.  It is brute-force
    scored once per query — by ``ops.delta_scan`` on the per-probe
    path, or *inside* the fused kernel as a second prefetch stream —
    and each entry is merged into the running top-k at the probe of
    its *assigned* cluster, so phi/patience accounting — and therefore
    the result — is bit-identical to searching a rebuilt index that
    physically contains the delta docs in those lists.  Tombstoned
    docs carry stored id -1 and are masked on every path.
    """
    if use_fused_kernel or use_scan_kernel:
        # the kernels trust blk_l-aligned offsets: fail loudly up front
        validate_alignment(index, blk_l=blk_l)
    return _search(index, queries, policy, delta,
                   use_scan_kernel=use_scan_kernel,
                   use_topk_kernel=use_topk_kernel,
                   use_fused_kernel=use_fused_kernel, chunk=chunk,
                   blk_l=blk_l)


@functools.partial(
    jax.jit, static_argnames=("use_scan_kernel", "use_topk_kernel",
                              "use_fused_kernel", "chunk", "blk_l"))
def _search(index: IVFIndex, queries: jnp.ndarray, policy: Policy,
            delta: Optional[DeltaView], *, use_scan_kernel: bool,
            use_topk_kernel: bool, use_fused_kernel: bool, chunk: int,
            blk_l: int) -> SearchResult:
    B, d = queries.shape
    k, N, tau = policy.k, policy.n_probe, policy.tau
    nc = index.n_clusters
    n_rank = min(N, nc)
    chunk = max(1, min(chunk, n_rank))
    # phi1 (vs RS_1) only feeds the learned-policy feature matrix
    needs_phi1 = policy.use_classifier or policy.use_reg

    csims = queries @ index.centroids.T                       # (B, C)
    rank_sims, cluster_rank = jax.lax.top_k(csims, n_rank)    # (B, N)

    if delta is not None and not use_fused_kernel:
        from repro.kernels import ops as kops
        # probe-0 brute-force scan of the whole delta buffer; each
        # entry is *merged* only at the probe of its assigned cluster.
        # (The fused path scores the buffer inside the kernel instead —
        # a second prefetch stream — so it skips this dispatch.)
        d_sc = kops.delta_scan(queries, delta.vecs)           # (B, cap)
        d_valid = (delta.ids >= 0)[None, :]                   # (1, cap)
        d_ids = jnp.broadcast_to(delta.ids[None, :], d_sc.shape)

    def delta_candidates(gate):
        """(B, cap) gated delta candidates: -inf / -1 outside gate."""
        return (jnp.where(gate, d_sc, -jnp.inf),
                jnp.where(gate, d_ids, -1))

    def probe_scores(cids):
        if use_scan_kernel:
            from repro.kernels import ops as kops
            lp = index.list_pad
            offs = jnp.take(index.cluster_offsets, cids)
            sizes = jnp.take(index.cluster_sizes, cids)
            sc = kops.ivf_scan(queries, index.docs, offs, sizes,
                               list_pad=lp, blk_l=blk_l)
            ids = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
                index.doc_ids, o, lp, axis=0))(offs)
            mask = jnp.arange(lp)[None, :] < sizes[:, None]
            ids = jnp.where(mask, ids, -1)
            return jnp.where(ids >= 0, sc, -jnp.inf), ids
        tiles, ids, mask = _probe_tiles(index, cids)
        sc = jnp.einsum("bld,bd->bl", tiles, queries)
        return jnp.where(mask, sc, -jnp.inf), ids

    init = SearchState(
        h=jnp.zeros((), jnp.int32),
        topk_scores=jnp.full((B, k), -jnp.inf, queries.dtype),
        topk_ids=jnp.full((B, k), -1, jnp.int32),
        rs1_ids=jnp.full((B, k), -1, jnp.int32),
        phi_hist=jnp.zeros((B, max(tau - 1, 1)), jnp.float32),
        phi1_hist=jnp.zeros((B, max(tau - 1, 1)), jnp.float32),
        centroid_sims=rank_sims[:, :tau].astype(jnp.float32),
        patience_ctr=jnp.zeros((B,), jnp.int32),
        target=jnp.full((B,), N, jnp.int32),
        active=jnp.ones((B,), bool),
        probes=jnp.zeros((B,), jnp.int32),
    )

    def cond(s: SearchState):
        return (s.h < n_rank) & jnp.any(s.active)

    def slot_update(s: SearchState, m_s, m_i, phi_pre) -> SearchState:
        """One probe's state transition given its merged top-k
        (snapshot or freshly merged) and, on the fused path, the
        kernel-derived phi (None -> recompute via intersection_pct)."""
        h = s.h
        act = s.active[:, None]
        topk_scores = jnp.where(act, m_s, s.topk_scores)
        topk_ids = jnp.where(act, m_i, s.topk_ids)

        phi = intersection_pct(s.topk_ids, topk_ids) \
            if phi_pre is None else phi_pre               # vs previous
        rs1_ids = jnp.where((h == 0)[None, None] & act, topk_ids, s.rs1_ids)

        # record stability history rows h-1 in [0, tau-2]
        hist_col = jnp.clip(h - 1, 0, max(tau - 2, 0))
        col_mask = (jnp.arange(s.phi_hist.shape[1]) == hist_col)[None, :]
        in_window = (h >= 1) & (h <= tau - 1)
        upd = col_mask & in_window & s.active[:, None]
        phi_hist = jnp.where(upd, phi[:, None], s.phi_hist)
        if needs_phi1:
            phi1 = intersection_pct(rs1_ids, topk_ids)
            phi1_hist = jnp.where(upd, phi1[:, None], s.phi1_hist)
        else:
            phi1_hist = s.phi1_hist

        extras = FeatureExtras(
            queries=queries, centroid_sims=s.centroid_sims,
            topk_scores=topk_scores, phi_hist=phi_hist, phi1_hist=phi1_hist)

        dec: PolicyDecision = policy_step(
            policy, h=h, phi=phi, patience_ctr=s.patience_ctr,
            target=s.target, extras=extras)

        exit_now = s.active & dec.exit & (h + 1 >= policy.min_probes)
        probes = jnp.where(s.active, h + 1, s.probes)
        active = s.active & ~exit_now & (h + 1 < n_rank)
        return SearchState(h + 1, topk_scores, topk_ids, rs1_ids, phi_hist,
                           phi1_hist, s.centroid_sims, dec.patience_ctr,
                           dec.target, active, probes)

    def body(s: SearchState) -> SearchState:
        if use_fused_kernel:
            from repro.kernels import ops as kops
            # one fused dispatch scores+merges the whole probe chunk;
            # slots past n_rank get size 0 so they merge nothing
            rel = jnp.arange(chunk, dtype=jnp.int32)
            idx = jnp.clip(s.h + rel, 0, n_rank - 1)
            cids = jnp.take(cluster_rank, idx, axis=1)        # (B, chunk)
            offs = jnp.take(index.cluster_offsets, cids)
            slot_ok = (s.h + rel < n_rank)[None, :]
            sizes = jnp.where(slot_ok,
                              jnp.take(index.cluster_sizes, cids), 0)
            if delta is not None:
                # delta buffer rides the kernel as a second prefetch
                # stream; each entry merges at its assigned cluster's
                # probe slot.  Slots past the budget gate on -2 (an
                # empty slot's assign is -1, a real cluster id >= 0).
                gates = jnp.where(slot_ok, cids, -2)
                snap_s, snap_i, cnts = kops.ivf_scan_merge(
                    queries, index.docs, index.doc_ids, offs, sizes,
                    s.topk_scores, s.topk_ids, delta.vecs, delta.ids,
                    delta.assign, gates, k=k,
                    list_pad=index.list_pad, chunk=chunk, blk_l=blk_l)
            else:
                snap_s, snap_i, cnts = kops.ivf_scan_merge(
                    queries, index.docs, index.doc_ids, offs, sizes,
                    s.topk_scores, s.topk_ids, k=k,
                    list_pad=index.list_pad, chunk=chunk, blk_l=blk_l)
        st = s
        for t in range(chunk):
            if use_fused_kernel:
                phi_pre = (100.0
                           * (k - cnts[:, t]).astype(jnp.float32) / k)
                st = slot_update(st, snap_s[:, t], snap_i[:, t],
                                 phi_pre)
            else:
                probe_idx = jnp.broadcast_to(
                    jnp.minimum(st.h, n_rank - 1), (B,))
                cids = jnp.take_along_axis(
                    cluster_rank, probe_idx[:, None], axis=1)[:, 0]
                new_scores, new_ids = probe_scores(cids)
                if delta is not None:
                    gate = d_valid & (delta.assign[None, :]
                                      == cids[:, None])
                    e_s, e_i = delta_candidates(gate)
                    new_scores = jnp.concatenate([new_scores, e_s], 1)
                    new_ids = jnp.concatenate([new_ids, e_i], 1)
                m_s, m_i = _merge_topk(st.topk_scores, st.topk_ids,
                                       new_scores, new_ids, k,
                                       use_topk_kernel)
                st = slot_update(st, m_s, m_i, None)
        return st

    final = jax.lax.while_loop(cond, body, init)
    return SearchResult(final.topk_scores, final.topk_ids, final.probes,
                        final.phi_hist)


@functools.partial(jax.jit, static_argnames=("tau", "k", "with_intersections"))
def extract_features(index: IVFIndex, queries: jnp.ndarray, *, tau: int,
                     k: int, with_intersections: bool = True) -> jnp.ndarray:
    """Run exactly ``tau`` probes and build the Table-1 feature matrix.

    This is the same code path the jitted search uses at h == tau, so
    offline (training) and online (serving) features match bit-for-bit.
    """
    from repro.core.features import FeatureExtras as FE, feature_matrix
    B = queries.shape[0]
    csims = queries @ index.centroids.T
    rank_sims, cluster_rank = jax.lax.top_k(csims, min(tau, index.n_clusters))

    def step(carry, h):
        scores, ids, rs1, phi_h, phi1_h = carry
        tiles, tids, mask = _probe_tiles(index, cluster_rank[:, h])
        sc = jnp.where(mask, jnp.einsum("bld,bd->bl", tiles, queries),
                       -jnp.inf)
        ns, ni = _merge_topk(scores, ids, sc, tids, k)
        phi = intersection_pct(ids, ni)
        rs1 = jnp.where(h == 0, ni, rs1)
        phi1 = intersection_pct(rs1, ni)
        col = jnp.clip(h - 1, 0, max(tau - 2, 0))
        colm = (jnp.arange(max(tau - 1, 1)) == col)[None, :] & (h >= 1)
        phi_h = jnp.where(colm, phi[:, None], phi_h)
        phi1_h = jnp.where(colm, phi1[:, None], phi1_h)
        return (ns, ni, rs1, phi_h, phi1_h), None

    init = (jnp.full((B, k), -jnp.inf, queries.dtype),
            jnp.full((B, k), -1, jnp.int32),
            jnp.full((B, k), -1, jnp.int32),
            jnp.zeros((B, max(tau - 1, 1)), jnp.float32),
            jnp.zeros((B, max(tau - 1, 1)), jnp.float32))
    (scores, ids, rs1, phi_h, phi1_h), _ = jax.lax.scan(
        step, init, jnp.arange(min(tau, index.n_clusters)))
    extras = FE(queries=queries, centroid_sims=rank_sims.astype(jnp.float32),
                topk_scores=scores, phi_hist=phi_h, phi1_hist=phi1_h)
    return feature_matrix(extras, with_intersections=with_intersections)


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force(docs: jnp.ndarray, queries: jnp.ndarray, k: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact kNN oracle (id space = row index)."""
    sims = queries @ docs.T
    s, i = jax.lax.top_k(sims, k)
    return s, i.astype(jnp.int32)


def probe_trace(index: IVFIndex, queries: jnp.ndarray, n_probe: int, k: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Reference (non-exiting) scan returning the full top-k trajectory:
    ids after every probe h=1..N. Used for C(q) labels, Figure 1 and
    policy oracles. Returns (ids_traj (N,B,k), phi (N-1,B))."""
    B = queries.shape[0]
    csims = queries @ index.centroids.T
    _, cluster_rank = jax.lax.top_k(csims, min(n_probe, index.n_clusters))

    def step(carry, h):
        scores, ids = carry
        cids = cluster_rank[:, h]
        tiles, tids, mask = _probe_tiles(index, cids)
        sc = jnp.einsum("bld,bd->bl", tiles, queries)
        sc = jnp.where(mask, sc, -jnp.inf)
        ns, ni = _merge_topk(scores, ids, sc, tids, k)
        return (ns, ni), ni

    init = (jnp.full((B, k), -jnp.inf, queries.dtype),
            jnp.full((B, k), -1, jnp.int32))
    _, traj = jax.lax.scan(step, init,
                           jnp.arange(min(n_probe, index.n_clusters)))
    traj = np.asarray(traj)
    phi = np.stack([np.asarray(intersection_pct(jnp.asarray(traj[h - 1]),
                                                jnp.asarray(traj[h])))
                    for h in range(1, traj.shape[0])])
    return traj, phi


def min_probes_labels(traj_ids: np.ndarray, exact_top1: np.ndarray,
                      n_probe: int) -> np.ndarray:
    """C(q): minimal h such that RS_h contains the exact 1-NN (else N)."""
    n, b, _ = traj_ids.shape
    found = (traj_ids == exact_top1[None, :, None]).any(-1)  # (N, B)
    any_found = found.any(0)
    first = np.argmax(found, axis=0) + 1
    return np.where(any_found, first, n_probe).astype(np.int32)
