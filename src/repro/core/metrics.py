"""Effectiveness metrics from the paper: R*@1, R*@k, R@k, mRR@10."""
from __future__ import annotations

from typing import Dict

import numpy as np


def r_star_at_1(result_ids: np.ndarray, exact_top1: np.ndarray) -> float:
    """Fraction of queries whose top-1 equals the exact 1-NN."""
    return float(np.mean(result_ids[:, 0] == exact_top1))


def r_star_at_k(result_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """Mean overlap between the approximate and exact top-k sets."""
    k = exact_ids.shape[1]
    inter = (result_ids[:, :, None] == exact_ids[:, None, :]).any(-1)
    return float(np.mean(inter.sum(1) / k))

def recall_at_k(result_ids: np.ndarray, relevant: np.ndarray) -> float:
    """R@k against the single labelled relevant doc per query."""
    return float(np.mean((result_ids == relevant[:, None]).any(1)))


def mrr_at_10(result_ids: np.ndarray, relevant: np.ndarray) -> float:
    top10 = result_ids[:, :10]
    hit = top10 == relevant[:, None]
    rank = np.argmax(hit, 1) + 1
    rr = np.where(hit.any(1), 1.0 / rank, 0.0)
    return float(np.mean(rr))


def summarize(result_ids: np.ndarray, probes: np.ndarray,
              exact_ids: np.ndarray, relevant: np.ndarray,
              wall_ms: float = float("nan")) -> Dict[str, float]:
    return {
        "R*@1": r_star_at_1(result_ids, exact_ids[:, 0]),
        "R*@k": r_star_at_k(result_ids, exact_ids),
        "R@100": recall_at_k(result_ids, relevant),
        "mRR@10": mrr_at_10(result_ids, relevant),
        "C": float(np.mean(probes)),
        "T_ms": wall_ms,
    }
