"""Table 1 feature extraction (shared by training and the jitted search).

Feature layout (order is part of the model contract):
  [0, d)                      query vector                       (group 1)
  [d, d+tau)                  similarity to h-th closest centroid (group 2)
  [d+tau]                     sigma_tau(q, d_1)   max doc sim     (group 3)
  [d+tau+1]                   sigma_tau(q, d_k)   k-th doc sim
  [d+tau+2]                   sigma(d_1)/sigma(d_k)
  [d+tau+3]                   sigma(d_1)/sigma(c_1)
  [d+tau+4, d+tau+4+(tau-1))  |RS_{h-1} ∩ RS_h|/k, h=2..tau      (group 4)
  [.., +(tau-1))              |RS_1 ∩ RS_h|/k,     h=2..tau
REG (Li et al.) uses groups 1-3 only; REG+int and the Classifier use all.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class FeatureExtras(NamedTuple):
    queries: jnp.ndarray        # (B, d)
    centroid_sims: jnp.ndarray  # (B, tau)
    topk_scores: jnp.ndarray    # (B, k) current result-set scores
    phi_hist: jnp.ndarray       # (B, tau-1) consecutive intersections (%)
    phi1_hist: jnp.ndarray      # (B, tau-1) intersections with RS_1 (%)


def n_features(dim: int, tau: int, with_intersections: bool) -> int:
    base = dim + tau + 4
    return base + 2 * (tau - 1) if with_intersections else base


def feature_matrix(extras: FeatureExtras, *, with_intersections: bool
                   ) -> jnp.ndarray:
    """(B, F) feature matrix; safe under -inf placeholder scores."""
    q = extras.queries.astype(jnp.float32)
    cs = extras.centroid_sims.astype(jnp.float32)
    scores = extras.topk_scores.astype(jnp.float32)
    finite = jnp.isfinite(scores)
    scores = jnp.where(finite, scores, 0.0)
    s1 = scores[:, 0]
    sk = scores[:, -1]
    eps = 1e-6
    r_1k = s1 / jnp.where(jnp.abs(sk) > eps, sk, jnp.sign(sk) * eps + eps)
    c1 = cs[:, 0]
    r_1c = s1 / jnp.where(jnp.abs(c1) > eps, c1, jnp.sign(c1) * eps + eps)
    cols = [q, cs, s1[:, None], sk[:, None], r_1k[:, None], r_1c[:, None]]
    if with_intersections:
        cols += [extras.phi_hist / 100.0, extras.phi1_hist / 100.0]
    return jnp.concatenate(cols, axis=1)
