"""Distributed IVF search: clusters sharded over `model`, queries over
the DP axes (DESIGN §5).

Each device owns ~C/S clusters (round-robin by size rank, which
balances list bytes). One *distributed probe step* probes each shard's
next-best local cluster (S probes per step); the per-shard top-k
candidates are all-gathered (k entries each — tiny) and merged
identically on every shard, so patience/early-exit decisions match the
single-host semantics on the merged result set.

Paper-semantics note: probing the union of per-shard top-(N/S) clusters
is the standard distributed IVF approximation of the global top-N probe
order; with round-robin sharding the probed sets coincide with high
probability. Probe counts are reported in *clusters*, comparable to the
paper's C column.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ivf import IVFIndex, _merge_topk, intersection_pct


@dataclasses.dataclass
class ShardedIVF:
    """Host-side container of per-shard stacked arrays (leading S dim)."""
    centroids: np.ndarray   # (S, Cs, d); padding centroids = +inf-far
    docs: np.ndarray        # (S, n_pad, d) f32/bf16/int8
    doc_ids: np.ndarray     # (S, n_pad)
    offsets: np.ndarray     # (S, Cs)
    sizes: np.ndarray       # (S, Cs)
    list_pad: int
    n_shards: int
    doc_scales: "np.ndarray | None" = None  # (S, n_pad) int8 row scales


def shard_index(index: IVFIndex, n_shards: int) -> ShardedIVF:
    cent = np.asarray(index.centroids)
    docs = np.asarray(index.docs)
    ids = np.asarray(index.doc_ids)
    offs = np.asarray(index.cluster_offsets)
    sizes = np.asarray(index.cluster_sizes)
    c, d = cent.shape
    lp = index.list_pad
    # round-robin by size rank -> balanced bytes per shard
    order = np.argsort(-sizes, kind="stable")
    shard_of = np.empty(c, np.int32)
    shard_of[order] = np.arange(c) % n_shards
    cs = int(np.ceil(c / n_shards))
    np_rows = int(max(sizes[shard_of == s].sum()
                      for s in range(n_shards))) + lp
    s_cent = np.full((n_shards, cs, d), -1e30, np.float32)
    s_docs = np.zeros((n_shards, np_rows, d), np.float32)
    s_ids = np.full((n_shards, np_rows), -1, np.int32)
    s_offs = np.zeros((n_shards, cs), np.int32)
    s_sizes = np.zeros((n_shards, cs), np.int32)
    for s in range(n_shards):
        mine = np.nonzero(shard_of == s)[0]
        row = 0
        for j, cid in enumerate(mine):
            sz = int(sizes[cid])
            s_cent[s, j] = cent[cid]
            s_offs[s, j] = row
            s_sizes[s, j] = sz
            s_docs[s, row: row + sz] = docs[offs[cid]: offs[cid] + sz]
            s_ids[s, row: row + sz] = ids[offs[cid]: offs[cid] + sz]
            row += sz
    return ShardedIVF(s_cent, s_docs, s_ids, s_offs, s_sizes, lp, n_shards)


def abstract_sharded(n_docs: int, dim: int, n_clusters: int, list_pad: int,
                     n_shards: int, dtype=jnp.float32) -> ShardedIVF:
    sd = jax.ShapeDtypeStruct
    cs = int(np.ceil(n_clusters / n_shards))
    rows = n_docs // n_shards + 2 * list_pad
    scales = sd((n_shards, rows), jnp.float32) if dtype == jnp.int8 \
        else None
    return ShardedIVF(sd((n_shards, cs, dim),
                         jnp.bfloat16 if dtype == jnp.int8 else dtype),
                      sd((n_shards, rows, dim), dtype),
                      sd((n_shards, rows), jnp.int32),
                      sd((n_shards, cs), jnp.int32),
                      sd((n_shards, cs), jnp.int32), list_pad, n_shards,
                      scales)


def quantize_sharded(sh: ShardedIVF) -> ShardedIVF:
    """Symmetric per-row int8 quantisation of the doc store (§Perf
    iteration 3): scores are corrected by the row scale *after* the
    dot, so the HBM stream is 4x smaller than f32."""
    docs = np.asarray(sh.docs, np.float32)
    scale = np.maximum(np.abs(docs).max(-1), 1e-8) / 127.0
    q = np.clip(np.round(docs / scale[..., None]), -127, 127) \
        .astype(np.int8)
    return ShardedIVF(sh.centroids.astype(np.float32), q, sh.doc_ids,
                      sh.offsets, sh.sizes, sh.list_pad, sh.n_shards,
                      scale.astype(np.float32))


class DistSearchResult(NamedTuple):
    topk_scores: jnp.ndarray   # (B, k)
    topk_ids: jnp.ndarray      # (B, k)
    probes: jnp.ndarray        # (B,) clusters scanned (global count)


# -- fault-tolerant shard fan-out (host-coordinated data plane) -------------

class ShardFault(RuntimeError):
    """A shard probe failed or timed out (real or injected)."""


@dataclasses.dataclass
class ShardRetryReport:
    attempts: int = 0                  # total shard dispatches issued
    retries: int = 0                   # dispatches beyond the first try
    skipped_shards: list = dataclasses.field(default_factory=list)
    lost_clusters: int = 0             # clusters owned by skipped shards
    backoff_ms: float = 0.0            # cumulative backoff slept
    budget_ms: float = float("inf")    # per-query total-backoff budget
    budget_exhausted: bool = False     # the budget ran dry this query
    budget_skips: int = 0              # shards skipped WITHOUT waiting
    #                                    out retries once it ran dry


@functools.partial(jax.jit, static_argnames=("k", "n_local", "list_pad"))
def _shard_local_topk(centroids, docs, doc_ids, offsets, sizes, queries,
                      *, k: int, n_local: int, list_pad: int):
    """One shard's top-k over its ``n_local`` best local clusters."""
    csims = queries @ centroids.T                       # (B, Cs)
    n_rank = min(n_local, centroids.shape[0])
    _, rank = jax.lax.top_k(csims, n_rank)
    ts = jnp.full((queries.shape[0], k), -jnp.inf, jnp.float32)
    ti = jnp.full((queries.shape[0], k), -1, jnp.int32)
    for h in range(n_rank):
        cids = rank[:, h]
        offs = jnp.take(offsets, cids)
        szs = jnp.take(sizes, cids)
        tiles = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
            docs, o, list_pad, 0))(offs)                # (B, L, d)
        ids = jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(
            doc_ids, o, list_pad, 0))(offs)
        m = (jnp.arange(list_pad)[None, :] < szs[:, None]) & (ids >= 0)
        sc = jnp.einsum("bld,bd->bl", tiles, queries)
        sc = jnp.where(m, sc, -jnp.inf)
        ids = jnp.where(m, ids, -1)
        ts, ti = _merge_topk(ts, ti, sc, ids, k)
    return ts, ti


def search_with_retry(sharded: ShardedIVF, queries, *, k: int,
                      n_probe: int, retry=None, fault=None, sleep=None,
                      rng=None
                      ) -> Tuple[np.ndarray, np.ndarray,
                                 ShardRetryReport]:
    """Fan a query batch over IVF shards with per-shard retry + skip.

    The real-data-plane promotion of the ``runtime.straggler``
    simulation: each shard scans its top-``ceil(n_probe/S)`` local
    clusters; a shard whose dispatch raises :class:`ShardFault` (or
    ``TimeoutError``) is retried with the backoff schedule of
    ``retry`` (a ``repro.runtime.straggler.RetryPolicy``) and, after
    ``max_retries``, skipped — its clusters drop out of the candidate
    set and the loss is recorded in the returned
    :class:`ShardRetryReport` — so the wave *degrades* rather than
    dies.

    With ``retry.jitter="decorrelated"`` each backoff is a jittered
    draw (de-synchronising retry storms across concurrent queries);
    ``rng`` seeds it (``np.random.Generator``, defaults to a fixed
    seed for reproducibility).  ``retry.budget_ms`` caps the TOTAL
    backoff this query may sleep across all shards: once spent, a
    faulting shard is skipped immediately (``budget_skips``) instead
    of waiting out its remaining retries, so a multi-shard outage
    costs bounded latency.

    ``fault(shard, attempt)`` is the injection hook (chaos harness);
    ``sleep(ms)`` is injectable so tests and simulations don't block.
    """
    import time as _time

    from repro.runtime.straggler import RetryPolicy
    retry = retry or RetryPolicy()
    sleep = sleep if sleep is not None \
        else (lambda ms: _time.sleep(ms / 1000.0))
    if rng is None:
        rng = np.random.default_rng(0)
    q = jnp.asarray(queries, jnp.float32)
    n_local = -(-n_probe // sharded.n_shards)
    report = ShardRetryReport(budget_ms=retry.budget_ms)
    parts_s, parts_i = [], []
    for s in range(sharded.n_shards):
        got = None
        prev_ms = 0.0
        for attempt in range(retry.max_retries + 1):
            if attempt > 0 and report.budget_exhausted:
                # budget ran dry: degrade to skip-shard NOW rather
                # than sleeping out the remaining retries
                report.budget_skips += 1
                break
            report.attempts += 1
            if attempt > 0:
                report.retries += 1
                ms = retry.next_backoff(attempt - 1, prev_ms, rng)
                remaining = retry.budget_ms - report.backoff_ms
                if ms >= remaining:
                    ms = max(remaining, 0.0)
                    report.budget_exhausted = True
                prev_ms = ms
                report.backoff_ms += ms
                sleep(ms)
            try:
                if fault is not None:
                    fault(s, attempt)
                got = _shard_local_topk(
                    jnp.asarray(sharded.centroids[s], jnp.float32),
                    jnp.asarray(sharded.docs[s], jnp.float32),
                    jnp.asarray(sharded.doc_ids[s]),
                    jnp.asarray(sharded.offsets[s]),
                    jnp.asarray(sharded.sizes[s]), q, k=k,
                    n_local=n_local, list_pad=sharded.list_pad)
                break
            except (ShardFault, TimeoutError):
                continue
        if got is None:
            report.skipped_shards.append(s)
            report.lost_clusters += int(
                (np.asarray(sharded.sizes[s]) > 0).sum())
            continue
        parts_s.append(got[0])
        parts_i.append(got[1])
    if not parts_s:
        b = q.shape[0]
        return (np.full((b, k), -np.inf, np.float32),
                np.full((b, k), -1, np.int32), report)
    cat_s = jnp.concatenate(parts_s, axis=1)
    cat_i = jnp.concatenate(parts_i, axis=1)
    ts, idx = jax.lax.top_k(cat_s, k)
    ti = jnp.take_along_axis(cat_i, idx, axis=1)
    return np.asarray(ts), np.asarray(ti), report


def make_distributed_search(mesh, *, n_probe: int, k: int,
                            patience_delta: Optional[int] = None,
                            patience_phi: float = 95.0,
                            list_pad: int, model_axis: str = "model",
                            dp_axes: Tuple[str, ...] = ("data",),
                            unroll_steps: Optional[int] = None,
                            probe_width: int = 1,
                            int8_docs: bool = False):
    """Build the shard_map'd adaptive search for a (model x data) mesh.

    patience_delta=None -> fixed-N baseline. Returns
    fn(centroids, docs, doc_ids, offsets, sizes, queries) ->
    DistSearchResult.
    """
    from jax.sharding import PartitionSpec as P
    s_total = 1
    for a in (model_axis,) if isinstance(model_axis, str) else model_axis:
        s_total *= mesh.shape[a]
    w = probe_width
    n_steps = int(np.ceil(n_probe / (s_total * w)))

    def local_fn(centroids, docs, doc_ids, offsets, sizes, queries,
                 doc_scales=None):
        # local blocks keep the sharded leading dim as size 1 — squeeze
        centroids, docs, doc_ids = centroids[0], docs[0], doc_ids[0]
        offsets, sizes = offsets[0], sizes[0]
        if doc_scales is not None:
            doc_scales = doc_scales[0]
        b = queries.shape[0]
        cs = centroids.shape[0]
        queries = queries.astype(centroids.dtype)
        csims = jax.lax.dot_general(
            queries, centroids, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (B, Cs)
        n_rank = min(n_steps, max(cs // w, 1))
        _, rank = jax.lax.top_k(csims, min(n_rank * w, cs))

        def probe(h_vec):
            # probe_width clusters per step: one merge/all-gather
            # amortised over w scans (§Perf iteration 2)
            base = h_vec[:, None] * w + jnp.arange(w)[None, :]  # (B,w)
            base = jnp.minimum(base, rank.shape[1] - 1)
            cids = jnp.take_along_axis(rank, base, 1)            # (B,w)
            offs = jnp.take(offsets, cids)
            szs = jnp.take(sizes, cids)
            tiles = jax.vmap(jax.vmap(
                lambda o: jax.lax.dynamic_slice_in_dim(
                    docs, o, list_pad, 0)))(offs)                # (B,w,L,d)
            ids = jax.vmap(jax.vmap(
                lambda o: jax.lax.dynamic_slice_in_dim(
                    doc_ids, o, list_pad, 0)))(offs)
            m = jnp.arange(list_pad)[None, None] < szs[:, :, None]
            if doc_scales is not None:
                # int8 docs: dot in bf16, per-row scale folded AFTER the
                # dot (the dequantised tile is never materialised)
                row_scale = jax.vmap(jax.vmap(
                    lambda o: jax.lax.dynamic_slice_in_dim(
                        doc_scales, o, list_pad, 0)))(offs)   # (B,w,L)
                sc = jnp.einsum("bwld,bd->bwl",
                                tiles.astype(jnp.bfloat16),
                                queries.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
                sc = sc * row_scale
            else:
                sc = jnp.einsum("bwld,bd->bwl", tiles, queries,
                                preferred_element_type=jnp.float32)
            sc = jnp.where(m, sc, -jnp.inf).reshape(
                h_vec.shape[0], w * list_pad)
            ids = jnp.where(m, ids, -1).reshape(
                h_vec.shape[0], w * list_pad)
            return sc, ids, (szs > 0).sum(1)

        def merge_global(scores, ids):
            # (B,k) local -> all-gather tiny candidate sets -> (B,k)
            gs = jax.lax.all_gather(scores, model_axis)     # (S,B,k)
            gi = jax.lax.all_gather(ids, model_axis)
            gs = jnp.moveaxis(gs, 0, 1).reshape(b, -1)
            gi = jnp.moveaxis(gi, 0, 1).reshape(b, -1)
            ts, idx = jax.lax.top_k(gs, k)
            return ts, jnp.take_along_axis(gi, idx, 1)

        init = (jnp.zeros((), jnp.int32),
                jnp.full((b, k), -jnp.inf, jnp.float32),     # local topk
                jnp.full((b, k), -1, jnp.int32),
                jnp.full((b, k), -1, jnp.int32),             # global topk
                jnp.zeros((b,), jnp.int32),                  # patience
                jnp.ones((b,), bool),                        # active
                jnp.zeros((b,), jnp.int32))                  # probes

        def cond(st):
            return (st[0] < n_rank) & jnp.any(st[5])

        def body(st):
            h, lsc, lid, gprev, ctr, active, probes = st
            hv = jnp.broadcast_to(jnp.minimum(h, n_rank - 1), (b,))
            sc, ids, szs = probe(hv)
            nls, nli = _merge_topk(lsc, lid, sc, ids, k)
            lsc = jnp.where(active[:, None], nls, lsc)
            lid = jnp.where(active[:, None], nli, lid)
            gs, gi = merge_global(lsc, lid)
            phi = intersection_pct(gprev, gi)
            scanned = jax.lax.psum(
                szs.astype(jnp.int32) * active.astype(jnp.int32),
                model_axis)
            probes = probes + jnp.where(active, scanned, 0)
            if patience_delta is not None:
                ctr = jnp.where((h >= 1) & (phi >= patience_phi),
                                ctr + 1, 0)
                exited = ctr >= patience_delta
            else:
                exited = jnp.zeros((b,), bool)
            active = active & ~exited & (h + 1 < n_rank)
            return (h + 1, lsc, lid, gi, ctr, active, probes)

        if unroll_steps is not None:
            # unrolled fixed-step variant: no early exit, no while loop.
            # Used ONLY for roofline costing (XLA cost analysis counts
            # while bodies once — see launch/hlo_analysis.py).
            st = init
            for _ in range(unroll_steps):
                st = body(st)
            h, lsc, lid, gi, ctr, active, probes = st
        else:
            h, lsc, lid, gi, ctr, active, probes = jax.lax.while_loop(
                cond, body, init)
        gs, gi = merge_global(lsc, lid)
        return DistSearchResult(gs, gi, probes)

    P_ = jax.sharding.PartitionSpec
    in_specs = [P_(model_axis, None, None), P_(model_axis, None, None),
                P_(model_axis, None), P_(model_axis, None),
                P_(model_axis, None), P_(dp_axes, None)]
    if int8_docs:
        in_specs.append(P_(model_axis, None))
    return jax.shard_map(
        local_fn, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=DistSearchResult(
            P_(dp_axes, None), P_(dp_axes, None), P_(dp_axes)),
        check_vma=False)
