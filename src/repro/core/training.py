"""Offline training of the learned early-exit stages (REG / Classifier).

Mirrors the paper's methodology: split queries into train/valid/test,
compute golden labels C(q) (min probes to reach the exact 1-NN, else N),
extract Table-1 features after tau probes, train LightGBM-class forests
(our GBDT), with SMOTE + Exit-class weighting for the classifier.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import ivf
from repro.trees.gbdt import GBDT, Forest
from repro.trees.jax_infer import TreeEnsemble, from_numpy_forest
from repro.trees.smote import smote


@dataclass
class PolicyModels:
    reg: TreeEnsemble            # groups 1-3 (Li et al.)
    reg_int: TreeEnsemble        # all features (REG+int)
    clf: TreeEnsemble            # unweighted classifier
    clf_weighted: TreeEnsemble   # Exit-class weight w
    labels_train: np.ndarray     # C(q) on the train split (diagnostics)
    n_probe: int
    tau: int


def golden_labels(index: ivf.IVFIndex, queries: np.ndarray, docs: np.ndarray,
                  n_probe: int, k: int, block: int = 512) -> np.ndarray:
    """C(q) for every query (blocked to bound memory)."""
    out = np.empty(queries.shape[0], np.int32)
    for s in range(0, queries.shape[0], block):
        e = min(s + block, queries.shape[0])
        q = jnp.asarray(queries[s:e])
        _, top1 = ivf.brute_force(jnp.asarray(docs), q, 1)
        traj, _ = ivf.probe_trace(index, q, n_probe, k)
        out[s:e] = ivf.min_probes_labels(traj, np.asarray(top1)[:, 0],
                                         n_probe)
    return out


def features_blocked(index: ivf.IVFIndex, queries: np.ndarray, *, tau: int,
                     k: int, block: int = 1024) -> np.ndarray:
    outs = []
    for s in range(0, queries.shape[0], block):
        q = jnp.asarray(queries[s: s + block])
        outs.append(np.asarray(ivf.extract_features(
            index, q, tau=tau, k=k, with_intersections=True)))
    return np.concatenate(outs, 0)


def train_policy_models(index: ivf.IVFIndex, docs: np.ndarray,
                        train_q: np.ndarray, valid_q: np.ndarray, *,
                        n_probe: int, k: int = 100, tau: int = 10,
                        exit_weight: float = 3.0, n_trees: int = 100,
                        max_depth: int = 6, seed: int = 0,
                        n_base_features: Optional[int] = None
                        ) -> PolicyModels:
    dim = index.dim
    nb = n_base_features if n_base_features is not None else dim + tau + 4

    y_tr = golden_labels(index, train_q, docs, n_probe, k)
    y_va = golden_labels(index, valid_q, docs, n_probe, k)
    x_tr = features_blocked(index, train_q, tau=tau, k=k)
    x_va = features_blocked(index, valid_q, tau=tau, k=k)

    # --- REG (groups 1-3) & REG+int (all features) ---
    reg_model = GBDT("l2", n_trees=n_trees, max_depth=max_depth, seed=seed)
    f_reg = reg_model.fit(x_tr[:, :nb], y_tr.astype(np.float64),
                          eval_set=(x_va[:, :nb], y_va.astype(np.float64)))
    f_reg_int = reg_model.fit(x_tr, y_tr.astype(np.float64),
                              eval_set=(x_va, y_va.astype(np.float64)))

    # --- Classifier: Exit iff C(q) <= tau; SMOTE on the minority class,
    # then instance weight w on the Exit class (paper: penalise F-Exits) ---
    c_tr = (y_tr <= tau).astype(np.float64)   # Exit = 1
    c_va = (y_va <= tau).astype(np.float64)
    xs, cs = smote(x_tr, c_tr, seed=seed)
    clf_model = GBDT("logistic", n_trees=n_trees, max_depth=max_depth,
                     seed=seed)
    f_clf = clf_model.fit(xs, cs, eval_set=(x_va, c_va))
    w = np.where(cs == 1.0, exit_weight, 1.0)
    f_clf_w = clf_model.fit(xs, cs, sample_weight=w, eval_set=(x_va, c_va))

    return PolicyModels(
        reg=from_numpy_forest(f_reg, max_depth),
        reg_int=from_numpy_forest(f_reg_int, max_depth),
        clf=from_numpy_forest(f_clf, max_depth),
        clf_weighted=from_numpy_forest(f_clf_w, max_depth),
        labels_train=y_tr, n_probe=n_probe, tau=tau)


def choose_n_probe(index: ivf.IVFIndex, docs: np.ndarray,
                   queries: np.ndarray, *, rho: float = 0.95, k: int = 100,
                   n_max: int = 256, block: int = 512) -> int:
    """Paper §2: minimum N with R*@1 >= rho on a tuning query set."""
    hits = np.zeros(n_max, np.int64)
    total = 0
    for s in range(0, queries.shape[0], block):
        e = min(s + block, queries.shape[0])
        q = jnp.asarray(queries[s:e])
        _, top1 = ivf.brute_force(jnp.asarray(docs), q, 1)
        traj, _ = ivf.probe_trace(index, q, n_max, k)
        found = (traj == np.asarray(top1)[None, :, :1]).any(-1)  # (N, b)
        hit_at = np.cumsum(found, 0) > 0                          # (N, b)
        hits += hit_at.sum(1)
        total += e - s
    recall = hits / total
    ok = np.nonzero(recall >= rho)[0]
    return int(ok[0]) + 1 if ok.size else n_max
