"""Batched Lloyd k-means in JAX (the IVF coarse quantizer).

FAISS trains the IVF coarse quantizer with k-means on a sample of the
corpus; we do the same. The assignment step is a blocked matmul (MXU
friendly); the update step is a segment_sum. A shard_map variant
distributes the assignment over the `data` mesh axis for corpus-scale
builds (used by the ivf_build dry-run cell).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _assign_block(x: jnp.ndarray, centroids: jnp.ndarray,
                  block: int = 4096) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Nearest-centroid assignment by inner product, blocked over rows."""
    n = x.shape[0]
    block = min(block, n)
    c_sq = jnp.sum(centroids * centroids, axis=1)  # (C,)
    n_pad = ((n + block - 1) // block) * block
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0))) if n_pad != n else x

    def body(i, carry):
        assign, best = carry
        xb = jax.lax.dynamic_slice_in_dim(xp, i * block, block, axis=0)
        # squared L2 = |x|^2 - 2 x.c + |c|^2 ; |x|^2 constant per row
        sims = xb @ centroids.T - 0.5 * c_sq[None, :]
        a = jnp.argmax(sims, axis=1).astype(jnp.int32)
        s = jnp.max(sims, axis=1)
        assign = jax.lax.dynamic_update_slice_in_dim(assign, a, i * block, 0)
        best = jax.lax.dynamic_update_slice_in_dim(best, s, i * block, 0)
        return assign, best

    assign = jnp.zeros((n_pad,), jnp.int32)
    best = jnp.zeros((n_pad,), x.dtype)
    assign, best = jax.lax.fori_loop(0, n_pad // block, body,
                                     (assign, best), unroll=False)
    return assign[:n], best[:n]


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iters", "block"))
def kmeans_fit(x: jnp.ndarray, init: jnp.ndarray, *, n_clusters: int,
               n_iters: int = 10, block: int = 4096) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd iterations from ``init`` centroids. Returns (centroids, assign)."""

    def step(carry, _):
        centroids = carry
        assign, _ = _assign_block(x, centroids, block)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), assign,
                                     num_segments=n_clusters)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        # keep old centroid for empty clusters
        centroids = jnp.where((counts > 0)[:, None], new, centroids)
        return centroids, counts

    centroids, _ = jax.lax.scan(step, init, None, length=n_iters)
    assign, _ = _assign_block(x, centroids, block)
    return centroids, assign


def kmeans(x: np.ndarray, n_clusters: int, *, n_iters: int = 10,
           seed: int = 0, block: int = 4096) -> Tuple[np.ndarray, np.ndarray]:
    """Host entry point: random-sample init (FAISS default) + Lloyd."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(n_clusters, n), replace=False)
    init = np.asarray(x[idx], dtype=np.float32)
    if init.shape[0] < n_clusters:  # corpus smaller than C: jitter duplicates
        extra = init[rng.integers(0, init.shape[0], n_clusters - init.shape[0])]
        extra = extra + rng.normal(0, 1e-3, extra.shape).astype(np.float32)
        init = np.concatenate([init, extra], 0)
    centroids, assign = kmeans_fit(jnp.asarray(x, jnp.float32),
                                   jnp.asarray(init), n_clusters=n_clusters,
                                   n_iters=n_iters, block=block)
    return np.asarray(centroids), np.asarray(assign)


def retrain(x: np.ndarray, centroids: np.ndarray, *, n_iters: int = 4,
            block: int = 4096) -> Tuple[np.ndarray, np.ndarray]:
    """Warm-start Lloyd re-fit for background re-clustering.

    Starts from the serving centroids (already near the corpus modes,
    so a few iterations suffice) and keeps the cluster COUNT fixed —
    no ``split_oversized`` — so the rebuilt index keeps its compiled
    search shapes; entries overflowing ``list_pad`` under the new
    assignment spill into the rebuild candidate's delta buffer exactly
    like ``merge_delta`` spill-back.  Deterministic: same (corpus,
    centroids, n_iters) always yields the same result, which is what
    lets the rebuild chaos drill demand bit-identical recovery.

    Returns ``(new_centroids, assign)`` as host arrays.  An empty
    corpus returns the input centroids unchanged.
    """
    centroids = np.asarray(centroids, np.float32)
    x = np.asarray(x, np.float32)
    if x.shape[0] == 0:
        return centroids.copy(), np.zeros(0, np.int32)
    new, assign = kmeans_fit(jnp.asarray(x), jnp.asarray(centroids),
                             n_clusters=centroids.shape[0],
                             n_iters=n_iters, block=block)
    return np.asarray(new), np.asarray(assign)


def sharded_assign_step(mesh, data_axis: str = "data"):
    """shard_map'd assignment+partial-stats step for corpus-scale k-means.

    Each data shard computes assignments for its rows and the *partial*
    (sum, count) statistics; a psum over the data axis yields the global
    Lloyd update. Used by the ``ivf_build`` dry-run cell.
    """
    from jax.sharding import PartitionSpec as P

    def local_step(x, centroids):
        assign, _ = _assign_block(x, centroids, 4096)
        nc = centroids.shape[0]
        sums = jax.ops.segment_sum(x, assign, num_segments=nc)
        counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), assign,
                                     num_segments=nc)
        sums = jax.lax.psum(sums, data_axis)
        counts = jax.lax.psum(counts, data_axis)
        new = jnp.where((counts > 0)[:, None],
                        sums / jnp.maximum(counts, 1.0)[:, None], centroids)
        return new, assign

    return jax.shard_map(local_step, mesh=mesh,
                         in_specs=(P(data_axis, None), P()),
                         out_specs=(P(), P(data_axis)),
                         check_vma=False)


def split_oversized(x: np.ndarray, centroids: np.ndarray, assign: np.ndarray,
                    max_size: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Recursively 2-means-split clusters larger than ``max_size``.

    Keeps every inverted list <= list_pad so a probe is exactly one
    contiguous (list_pad, d) tile (DESIGN §2: balanced IVF layout).
    """
    rng = np.random.default_rng(seed)
    centroids = list(np.asarray(centroids))
    assign = np.asarray(assign).copy()
    queue = [c for c in range(len(centroids))
             if int((assign == c).sum()) > max_size]
    while queue:
        c = queue.pop()
        members = np.nonzero(assign == c)[0]
        if members.size <= max_size:
            continue
        pts = x[members]
        # cheap 2-means: two random seeds, 4 Lloyd iterations
        seeds = pts[rng.choice(pts.shape[0], 2, replace=False)].copy()
        for _ in range(4):
            d0 = ((pts - seeds[0]) ** 2).sum(1)
            d1 = ((pts - seeds[1]) ** 2).sum(1)
            m1 = d1 < d0
            if m1.all() or (~m1).all():   # degenerate: split in half
                m1 = np.zeros(pts.shape[0], bool)
                m1[: pts.shape[0] // 2] = True
            seeds[0] = pts[~m1].mean(0)
            seeds[1] = pts[m1].mean(0)
        new_id = len(centroids)
        centroids[c] = seeds[0]
        centroids.append(seeds[1])
        assign[members[m1]] = new_id
        for cc in (c, new_id):
            if int((assign == cc).sum()) > max_size:
                queue.append(cc)
    return np.stack(centroids).astype(np.float32), assign
