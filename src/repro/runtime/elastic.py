"""Elastic scaling: checkpoints are mesh-agnostic (global arrays +
PartitionSpecs), so a job restarted on a different device count reshards
on restore. This module computes the new shardings and performs the
re-placement."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager

Pytree = Any


def remesh(n_devices: int, *, model_parallel: int,
           axis_names: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Build the largest (data, model) mesh fitting n_devices."""
    if n_devices % model_parallel:
        raise ValueError(f"{n_devices} devices not divisible by "
                         f"model_parallel={model_parallel}")
    shape = (n_devices // model_parallel, model_parallel)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def elastic_restore(ckpt: CheckpointManager, template: Pytree,
                    new_mesh: Mesh, pspecs: Pytree,
                    step: Optional[int] = None) -> Tuple[int, Pytree]:
    """Restore a checkpoint onto a *different* mesh (scale up/down)."""
    shardings = jax.tree.map(lambda s: NamedSharding(new_mesh, s), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
    return ckpt.restore(template, step=step, shardings=shardings)
