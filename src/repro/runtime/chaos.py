"""Chaos harness: deterministic seeded fault injection over a live
serving stack, measuring how gracefully it degrades.

Three drills, one JSON report (``artifacts/BENCH_resilience.json`` via
``python -m repro.launch.serve --chaos``):

* **Crash / recovery** — a seeded mutation stream (adds, deletes,
  merges) runs against a WAL-backed :class:`repro.index.LiveIndex`
  with periodic snapshots; :class:`SimulatedFailure` is injected at
  mutation boundaries, the process state is abandoned, and
  ``IndexRegistry.recover`` rebuilds it from snapshot + log replay.
  Reported: crash count, recovery wall time, replayed records, and a
  ``bit_identical`` bool (recovered results vs an uncrashed oracle,
  per-probe AND fused kernel paths).
* **Deadline sweep** — the query set is served under several
  ``deadline_ms`` budgets while a simulated clock injects latency
  spikes; the degradation ladder (tighten -> cap -> force -> shed) is
  the actuator.  Reported: recall-vs-deadline curve with degraded
  fractions and max budget overshoot.
* **Shard faults** — ``search_with_retry`` fan-out with seeded shard
  failures; retries/backoff/skips and residual recall are reported.

Everything is driven by one seed, so a chaos run is reproducible.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import SimulatedFailure


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    seed: int = 0
    # crash/recovery drill
    mutation_steps: int = 24       # mutation-boundary steps in the stream
    adds_per_step: int = 8
    crash_every: int = 7           # crash at every Nth boundary (0 = off)
    snapshot_every: int = 5        # registry.save cadence (boundaries)
    # deadline drill
    base_wave_ms: float = 1.0
    spike_rate: float = 0.15       # P(wave hits a latency spike)
    spike_ms: float = 8.0
    # shard drill
    n_shards: int = 4
    shard_fault_rate: float = 0.3  # P(one dispatch raises ShardFault)


class SimClock:
    """Deterministic ms clock, advanced explicitly by the harness."""

    def __init__(self, start_ms: float = 0.0):
        self.ms = float(start_ms)

    def __call__(self) -> float:
        return self.ms

    def advance(self, ms: float) -> None:
        self.ms += ms


class ChaosMonkey:
    """Seeded event source shared by the drills."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.clock = SimClock()
        self.spikes = 0
        self.shard_faults = 0

    def wave_ms(self) -> float:
        ms = self.cfg.base_wave_ms
        if self.rng.random() < self.cfg.spike_rate:
            self.spikes += 1
            ms += self.cfg.spike_ms
        return ms

    def tick_wave(self, wave: int) -> None:
        """on_wave hook: advance simulated time by one wave's cost."""
        self.clock.advance(self.wave_ms())

    def shard_fault(self, shard: int, attempt: int) -> None:
        """fault hook for ``search_with_retry``."""
        from repro.core.distributed_ivf import ShardFault
        if self.rng.random() < self.cfg.shard_fault_rate:
            self.shard_faults += 1
            raise ShardFault(
                f"chaos: shard {shard} fault (attempt {attempt})")


# ---------------------------------------------------------------------------
# drill 1: crash + WAL recovery over a live mutation stream
# ---------------------------------------------------------------------------

def _mutation_stream(cfg: ChaosConfig, docs: np.ndarray):
    """Deterministic (op, payload) list: adds of noisy corpus copies,
    deletes of previously added ids, periodic merges."""
    rng = np.random.default_rng(cfg.seed + 1)
    ops = []
    for step in range(cfg.mutation_steps):
        src = rng.integers(0, docs.shape[0], cfg.adds_per_step)
        noise = rng.normal(scale=0.05,
                           size=(cfg.adds_per_step, docs.shape[1]))
        ops.append(("add", (docs[src] + noise).astype(np.float32)))
        if step % 3 == 2:
            ops.append(("delete_recent", int(cfg.adds_per_step // 2)))
        if step % 6 == 5:
            ops.append(("merge", None))
    return ops


def _apply(live, op, payload, added: List[int]):
    from repro.index import DeltaFull
    if op == "add":
        try:
            added.extend(int(i) for i in live.add(payload))
        except DeltaFull:
            live.merge_delta()
            added.extend(int(i) for i in live.add(payload))
    elif op == "delete_recent":
        if len(added) >= payload:
            doomed = [added.pop() for _ in range(payload)]
            live.delete(doomed)
    else:
        live.merge_delta()


def run_crash_recovery(index, docs: np.ndarray, queries: np.ndarray,
                       cfg: ChaosConfig, workdir: str, *, k: int = 10,
                       n_probe: int = 16) -> Dict:
    """Kill-and-replay drill.  Returns recovery metrics including the
    bit-identity verdict against an uncrashed oracle."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core import policies, search
    from repro.index import (IndexRegistry, LiveIndex, MutationWAL,
                             version_of)

    # group commit on: durability batched across mutations, forced at
    # merge/snapshot boundaries — the drill proves recovery semantics
    # (torn tail, replay, bit-identity) are unchanged under batching
    wal = MutationWAL(os.path.join(workdir, "mutations.wal"),
                      group_commit_n=8, group_commit_ms=50.0)
    live = LiveIndex(index, delta_cap=4096, wal=wal)
    oracle = LiveIndex(index, delta_cap=4096)
    mgr = CheckpointManager(os.path.join(workdir, "snapshots"),
                            async_save=False, keep=2)
    reg = IndexRegistry(version_of(live))
    reg.save(mgr)                      # base snapshot (seq 0)

    crashes = 0
    recovery_ms: List[float] = []
    replayed = 0
    added_live: List[int] = []
    added_oracle: List[int] = []
    ops = _mutation_stream(cfg, docs)
    for step, (op, payload) in enumerate(ops):
        _apply(live, op, payload, added_live)
        _apply(oracle, op, payload, added_oracle)
        if cfg.crash_every and (step + 1) % cfg.crash_every == 0:
            crashes += 1
            try:
                raise SimulatedFailure(f"chaos crash @ boundary {step}")
            except SimulatedFailure:
                pass                   # process "dies" here
            t0 = time.monotonic()
            _, live, rep = IndexRegistry.recover(mgr, wal)
            recovery_ms.append((time.monotonic() - t0) * 1000.0)
            replayed += rep.applied
        if cfg.snapshot_every and (step + 1) % cfg.snapshot_every == 0:
            wal.flush()                # snapshot must not outrun the log
            reg = IndexRegistry(version_of(live))
            reg.save(mgr)
            wal.truncate_upto(live.seq)

    # bit-identity: recovered-and-continued live vs uncrashed oracle,
    # on both kernel paths
    q = jnp.asarray(queries)
    identical = True
    for kw in ({}, {"use_fused_kernel": True, "chunk": 4}):
        pol = policies.patience(n_probe, delta=2, phi=90.0, k=k, tau=3)
        a = live.search(q, pol, **kw)
        b = oracle.search(q, pol, **kw)
        identical &= bool(
            np.array_equal(np.asarray(a.topk_ids),
                           np.asarray(b.topk_ids))
            and np.array_equal(np.asarray(a.probes),
                               np.asarray(b.probes))
            and np.allclose(np.asarray(a.phi_hist),
                            np.asarray(b.phi_hist), atol=1e-4))
    wal.close()
    return {
        "crashes": crashes,
        "mutations": len(ops),
        "replayed_records": replayed,
        "mean_recovery_ms": float(np.mean(recovery_ms))
        if recovery_ms else 0.0,
        "max_recovery_ms": float(np.max(recovery_ms))
        if recovery_ms else 0.0,
        "final_seq": live.seq,
        "bit_identical": identical,
    }


# ---------------------------------------------------------------------------
# drill 2: recall-vs-deadline curve under latency spikes
# ---------------------------------------------------------------------------

def run_deadline_sweep(index, queries: np.ndarray,
                       exact_ids: np.ndarray, cfg: ChaosConfig,
                       deadlines_ms: List[float], *, k: int = 10,
                       n_probe: int = 16, delta: int = 3,
                       phi: float = 90.0, wave_size: int = 32,
                       chunk: int = 1) -> List[Dict]:
    from repro.core import metrics
    from repro.core.serving import WaveScheduler

    curve = []
    for dl in list(deadlines_ms) + [None]:     # None = no deadline row
        monkey = ChaosMonkey(cfg)              # fresh RNG per point
        ws = WaveScheduler(index, wave_size=wave_size, chunk=chunk,
                           k=k, n_probe=n_probe, delta=delta, phi=phi,
                           deadline_ms=dl, clock=monkey.clock)
        rep = ws.serve(queries, on_wave=monkey.tick_wave)
        nq = queries.shape[0]
        ids = np.stack([rep.results[i] for i in range(nq)])
        over = [rep.latency_ms[i] - dl for i in range(nq)
                if dl is not None and rep.latency_ms[i] > dl]
        reasons: Dict[str, int] = {}
        for r in rep.degraded.values():
            reasons[r] = reasons.get(r, 0) + 1
        curve.append({
            "deadline_ms": dl,
            "recall": round(metrics.r_star_at_k(ids, exact_ids), 4),
            "degraded_fraction": round(rep.degraded_fraction, 4),
            "reasons": reasons,
            "max_overshoot_ms": round(max(over, default=0.0), 3),
            "wave_cost_ms": round(rep.wave_cost_ms, 3),
            "waves": rep.waves,
            "spikes": monkey.spikes,
        })
    return curve


# ---------------------------------------------------------------------------
# drill 3: shard faults through the retry/backoff data plane
# ---------------------------------------------------------------------------

def run_shard_drill(index, queries: np.ndarray, exact_ids: np.ndarray,
                    cfg: ChaosConfig, *, k: int = 10,
                    n_probe: int = 16) -> Dict:
    from repro.core import metrics
    from repro.core.distributed_ivf import search_with_retry, shard_index
    from repro.runtime.straggler import RetryPolicy

    monkey = ChaosMonkey(cfg)
    sh = shard_index(index, cfg.n_shards)
    sleep_log = {"ms": 0.0}

    def sim_sleep(ms: float) -> None:
        sleep_log["ms"] += ms
        monkey.clock.advance(ms)

    _, ids_clean, _ = search_with_retry(
        sh, queries, k=k, n_probe=n_probe, sleep=sim_sleep)
    _, ids_chaos, rep = search_with_retry(
        sh, queries, k=k, n_probe=n_probe,
        retry=RetryPolicy(max_retries=3, base_ms=1.0),
        fault=monkey.shard_fault, sleep=sim_sleep)
    return {
        "n_shards": cfg.n_shards,
        "fault_rate": cfg.shard_fault_rate,
        "injected_faults": monkey.shard_faults,
        "attempts": rep.attempts,
        "retries": rep.retries,
        "skipped_shards": rep.skipped_shards,
        "lost_clusters": rep.lost_clusters,
        "backoff_ms": round(rep.backoff_ms, 3),
        "recall_clean": round(
            metrics.r_star_at_k(np.asarray(ids_clean), exact_ids), 4),
        "recall_chaos": round(
            metrics.r_star_at_k(np.asarray(ids_chaos), exact_ids), 4),
    }


# ---------------------------------------------------------------------------
# drill 4: background rebuild — crash boundaries, swap race, drift repair
# ---------------------------------------------------------------------------

def _search_identical(a_live, b_live, queries, *, k: int,
                      n_probe: int) -> bool:
    """Bit-identity of two LiveIndexes on per-probe AND fused paths."""
    from repro.core import policies
    q = jnp.asarray(queries)
    pol = policies.patience(n_probe, delta=2, phi=90.0, k=k, tau=3)
    same = True
    for kw in ({}, {"use_fused_kernel": True, "chunk": 4}):
        a = a_live.search(q, pol, **kw)
        b = b_live.search(q, pol, **kw)
        same &= bool(
            np.array_equal(np.asarray(a.topk_ids),
                           np.asarray(b.topk_ids))
            and np.array_equal(np.asarray(a.probes),
                               np.asarray(b.probes))
            and np.allclose(np.asarray(a.phi_hist),
                            np.asarray(b.phi_hist), atol=1e-4))
    return same


def _drive_rebuild(index, docs, cfg: ChaosConfig, workdir: str, tag: str,
                   failpoint: Optional[str]):
    """One scripted rebuild run: pre-mutations -> begin -> mid
    mutations -> retrain/layout/catchup -> late mutations + a racing
    ``merge_delta`` -> publish, crashing at ``failpoint`` (None = run
    to completion).  The schedule is deterministic, so a crashed run
    and its oracle (same schedule, no failpoint) see identical WAL
    streams up to the crash boundary.  Returns
    ``(wal, rebuilder, live, manager, registry, crashed_stage)``.
    """
    from repro.checkpoint.manager import CheckpointManager
    from repro.index import (IndexRegistry, LiveIndex, MutationWAL,
                             RebuildCrash, Rebuilder, version_of)

    wdir = os.path.join(workdir, f"rebuild_{tag}")
    os.makedirs(wdir, exist_ok=True)
    wal = MutationWAL(os.path.join(wdir, "mutations.wal"),
                      group_commit_n=8, group_commit_ms=50.0)
    live = LiveIndex(index, delta_cap=4096, wal=wal)
    mgr = CheckpointManager(os.path.join(wdir, "snapshots"),
                            async_save=False, keep=2)
    reg = IndexRegistry(version_of(live))
    reg.save(mgr)
    wal.note_durable(live.seq)

    rng = np.random.default_rng(cfg.seed + 11)

    def batch(n):
        src = rng.integers(0, docs.shape[0], n)
        noise = rng.normal(scale=0.05, size=(n, docs.shape[1]))
        return (docs[src] + noise).astype(np.float32)

    added: List[int] = []
    added.extend(int(i) for i in live.add(batch(cfg.adds_per_step)))
    live.delete([added.pop(), added.pop()])
    reg.publish(version_of(live))

    rb = Rebuilder(live, reg, mgr, n_iters=3, failpoint=failpoint)
    rb.request("chaos-drill")
    crashed = None
    try:
        while rb.active:
            stage = rb.tick()
            # mutations land after specific stages: post-begin ones
            # exercise the catch-up replay, post-catchup ones (plus a
            # merge_delta computed against the OLD centroids, i.e. a
            # merge racing the publish) exercise the publish-time
            # late-gap close
            if stage in ("begin", "catchup"):
                added.extend(int(i)
                             for i in live.add(batch(cfg.adds_per_step)))
                live.delete([added.pop()])
                if stage == "catchup":
                    live.merge_delta()
                reg.publish(version_of(live))
    except RebuildCrash:
        crashed = rb.stage
    return wal, rb, live, mgr, reg, crashed


def run_rebuild_drill(index, docs: np.ndarray, queries: np.ndarray,
                      cfg: ChaosConfig, workdir: str, *, k: int = 10,
                      n_probe: int = 16) -> Dict:
    """Rebuild lifecycle drill: crash at every two-phase-publish
    boundary (bit-identical recovery), epoch-fence a merge racing the
    publish (no lost mutations, no stale clobber), and show the
    drift-triggered rebuild restoring recall under sustained churn."""
    from repro.index import IndexRegistry
    from repro.index.rebuild import FAILPOINTS

    out: Dict = {}

    # -- 4a. crash at every rebuild boundary -------------------------------
    #    pre-COMMIT crashes must recover to the no-rebuild state;
    #    post-COMMIT crashes must recover to the post-rebuild state.
    boundaries = []
    for fp in FAILPOINTS:
        wal, rb, live, mgr, reg, crashed = _drive_rebuild(
            index, docs, cfg, workdir, f"crash_{fp}", fp)
        t0 = time.monotonic()
        _, recovered, rep = IndexRegistry.recover(mgr, wal)
        rec_ms = (time.monotonic() - t0) * 1000.0
        # a recovered epoch above the serving handle's means the crash
        # landed after the COMMIT record — the rebuild happened
        committed = recovered.epoch > live.epoch
        if committed:
            # oracle: the same scripted run, minus the crash (kmeans
            # and the mutation schedule are deterministic)
            _, orb, _, _, _, _ = _drive_rebuild(
                index, docs, cfg, workdir, f"oracle_{fp}", None)
            oracle = orb.live
        else:
            # recovery aborted the epoch, so it must land exactly on
            # the no-rebuild state — which the in-memory serving
            # handle still IS (only the Rebuilder crashed)
            oracle = live
        boundaries.append({
            "failpoint": fp,
            "crashed_stage": crashed,
            "resolution": "committed" if committed else "aborted",
            "promote_redone": bool(rep.rebuild_promoted),
            "abort_appended": bool(rep.rebuild_aborted),
            "recovered_epoch": int(recovered.epoch),
            "replayed_records": int(rep.applied),
            "recovery_ms": round(rec_ms, 2),
            "bit_identical": _search_identical(
                recovered, oracle, queries, k=k, n_probe=n_probe),
        })
        wal.close()
    out["crash_boundaries"] = boundaries

    # -- 4b. swap race: merge_delta vs rebuild publish ----------------------
    #    the scripted run merges the stale handle's delta between the
    #    catchup and publish ticks; the publish-stage late catch-up
    #    must fold that racing merge into the candidate, and the stale
    #    handle's own publish afterwards must be epoch-fenced.
    from repro.index import StaleEpochError, version_of
    wal, rb, live, mgr, reg, _ = _drive_rebuild(
        index, docs, cfg, workdir, "race", None)
    stale_ver = version_of(live)     # epoch 0, pre-rebuild centroids
    try:
        reg.publish(stale_ver)
        fenced = False
    except StaleEpochError:
        fenced = True
    cur = reg.current()
    # no lost mutations: every id the stale handle knows is serving
    new_ids = set(int(i) for i in rb.live.net_corpus()[1])
    old_ids = set(int(i) for i in live.net_corpus()[1])
    # crash right after the race: recovery must land on the rebuilt
    # epoch, bit-identical to the post-publish serving state
    _, recovered, _ = IndexRegistry.recover(mgr, wal)
    out["swap_race"] = {
        "fenced": fenced,
        "stale_epoch": int(stale_ver.epoch),
        "current_epoch": int(cur.epoch),
        "lost_mutations": len(old_ids - new_ids),
        "recovered_epoch": int(recovered.epoch),
        "recovered_bit_identical": _search_identical(
            recovered, rb.live, queries, k=k, n_probe=n_probe),
    }
    wal.close()

    # -- 4c. drift: churn shifts the corpus off its centroids --------------
    out["drift"] = run_drift_drill(cfg, k=k)
    return out


def run_drift_drill(cfg: ChaosConfig, *, k: int = 10, dim: int = 32,
                    n_clusters: int = 32, eval_probes: int = 8) -> Dict:
    """Sustained churn replaces the corpus with a blob mixture living
    in the OTHER half of the embedding space; each new doc also
    carries a small residual in the old half, so under FIXED centroids
    the blobs scatter across stale clusters in an order that is pure
    noise — a capped probe budget then finds only the few lists it
    happens to rank first and recall collapses.  A drift-triggered
    rebuild re-trains centroids onto the blobs, the probe ranking
    becomes informative again, and the same budget restores recall.
    Self-contained corpus (the geometry is the point), seeded by
    ``cfg.seed``."""
    from repro.core import metrics, policies
    from repro.core.ivf import build_index
    from repro.index import DriftTracker, LiveIndex, Rebuilder

    half = dim // 2
    rng = np.random.default_rng(cfg.seed + 23)
    # original corpus lives in the FIRST half of the embedding space
    base = np.zeros((2048, dim), np.float32)
    base[:, :half] = rng.normal(size=(2048, half))
    index = build_index(base, n_clusters=n_clusters, list_pad=256,
                        seed=cfg.seed, align=64)
    centers = rng.normal(scale=4.0, size=(8, half)).astype(np.float32)
    doomed = rng.permutation(2048)

    def blob_batch(rng, n=128):
        which = rng.integers(0, 8, n)
        out = np.zeros((n, dim), np.float32)
        out[:, :half] = 0.3 * rng.normal(size=(n, half))
        out[:, half:] = centers[which] + \
            rng.normal(scale=0.3, size=(n, half))
        return out

    def churn(live, tracker=None, rebuilder=None):
        rng = np.random.default_rng(cfg.seed + 29)
        trigger_ratio = 0.0
        for step in range(8):
            add = blob_batch(rng)
            live.add(add)
            live.delete(doomed[step * 192: (step + 1) * 192])
            live.merge_delta()
            if tracker is not None:
                tracker.observe(add)
                # trigger once drift is persistent (EMA warmed up),
                # late enough that the blob mass can anchor retrain
                if step >= 3 and tracker.triggered \
                        and rebuilder is not None \
                        and not rebuilder.epochs_published:
                    trigger_ratio = tracker.ratio
                    rebuilder.live = live
                    rebuilder.run_once("drift")
                    live = rebuilder.live
                    tracker.rebase(live._centroids)
        return live, trigger_ratio

    def eval_recall(live):
        rng = np.random.default_rng(cfg.seed + 31)
        q = np.zeros((64, dim), np.float32)
        q[:, :half] = 0.3 * rng.normal(size=(64, half))
        q[:, half:] = centers[rng.integers(0, 8, 64)] + \
            rng.normal(scale=0.3, size=(64, half))
        vecs, ids = live.net_corpus()
        exact = ids[np.argsort(-(q @ vecs.T), axis=1)[:, :k]]
        pol = policies.patience(min(eval_probes, n_clusters),
                                delta=2, phi=90.0, k=k, tau=3)
        res = live.search(jnp.asarray(q), pol)
        return (metrics.r_star_at_k(np.asarray(res.topk_ids), exact),
                float(np.mean(np.asarray(res.probes))))

    fixed, _ = churn(LiveIndex(index, delta_cap=4096))
    recall_fixed, probes_fixed = eval_recall(fixed)

    live = LiveIndex(index, delta_cap=4096)
    tracker = DriftTracker(live._centroids, base, ema=0.5, threshold=2.0)
    rb = Rebuilder(live, n_iters=8)
    rebuilt, trigger_ratio = churn(live, tracker, rb)
    recall_rebuilt, probes_rebuilt = eval_recall(rebuilt)

    return {
        "trigger_ratio": round(trigger_ratio, 2),
        "post_rebuild_ratio": round(tracker.ratio, 2),
        "rebuilds_triggered": rb.epochs_published,
        "recall_fixed": round(recall_fixed, 4),
        "recall_rebuilt": round(recall_rebuilt, 4),
        "mean_probes_fixed": round(probes_fixed, 1),
        "mean_probes_rebuilt": round(probes_rebuilt, 1),
        "recall_restored": recall_rebuilt > recall_fixed,
    }


# ---------------------------------------------------------------------------

def run_chaos(index, docs: np.ndarray, queries: np.ndarray,
              exact_ids: np.ndarray, cfg: ChaosConfig, workdir: str, *,
              k: int = 10, n_probe: int = 16,
              deadlines_ms: Optional[List[float]] = None) -> Dict:
    """All four drills; the returned dict is the
    ``BENCH_resilience.json`` payload."""
    deadlines_ms = deadlines_ms or [2.0, 5.0, 10.0, 25.0]
    t0 = time.monotonic()
    out = {
        "config": dataclasses.asdict(cfg),
        "recovery": run_crash_recovery(index, docs, queries, cfg,
                                       workdir, k=k, n_probe=n_probe),
        "deadline_curve": run_deadline_sweep(index, queries, exact_ids,
                                             cfg, deadlines_ms, k=k,
                                             n_probe=n_probe),
        "shard_faults": run_shard_drill(index, queries, exact_ids, cfg,
                                        k=k, n_probe=n_probe),
        "rebuild": run_rebuild_drill(index, docs, queries, cfg, workdir,
                                     k=k, n_probe=n_probe),
    }
    out["wall_s"] = round(time.monotonic() - t0, 1)
    return out
