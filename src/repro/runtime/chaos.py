"""Chaos harness: deterministic seeded fault injection over a live
serving stack, measuring how gracefully it degrades.

Three drills, one JSON report (``artifacts/BENCH_resilience.json`` via
``python -m repro.launch.serve --chaos``):

* **Crash / recovery** — a seeded mutation stream (adds, deletes,
  merges) runs against a WAL-backed :class:`repro.index.LiveIndex`
  with periodic snapshots; :class:`SimulatedFailure` is injected at
  mutation boundaries, the process state is abandoned, and
  ``IndexRegistry.recover`` rebuilds it from snapshot + log replay.
  Reported: crash count, recovery wall time, replayed records, and a
  ``bit_identical`` bool (recovered results vs an uncrashed oracle,
  per-probe AND fused kernel paths).
* **Deadline sweep** — the query set is served under several
  ``deadline_ms`` budgets while a simulated clock injects latency
  spikes; the degradation ladder (tighten -> cap -> force -> shed) is
  the actuator.  Reported: recall-vs-deadline curve with degraded
  fractions and max budget overshoot.
* **Shard faults** — ``search_with_retry`` fan-out with seeded shard
  failures; retries/backoff/skips and residual recall are reported.

Everything is driven by one seed, so a chaos run is reproducible.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.runtime.fault import SimulatedFailure


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    seed: int = 0
    # crash/recovery drill
    mutation_steps: int = 24       # mutation-boundary steps in the stream
    adds_per_step: int = 8
    crash_every: int = 7           # crash at every Nth boundary (0 = off)
    snapshot_every: int = 5        # registry.save cadence (boundaries)
    # deadline drill
    base_wave_ms: float = 1.0
    spike_rate: float = 0.15       # P(wave hits a latency spike)
    spike_ms: float = 8.0
    # shard drill
    n_shards: int = 4
    shard_fault_rate: float = 0.3  # P(one dispatch raises ShardFault)


class SimClock:
    """Deterministic ms clock, advanced explicitly by the harness."""

    def __init__(self, start_ms: float = 0.0):
        self.ms = float(start_ms)

    def __call__(self) -> float:
        return self.ms

    def advance(self, ms: float) -> None:
        self.ms += ms


class ChaosMonkey:
    """Seeded event source shared by the drills."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.clock = SimClock()
        self.spikes = 0
        self.shard_faults = 0

    def wave_ms(self) -> float:
        ms = self.cfg.base_wave_ms
        if self.rng.random() < self.cfg.spike_rate:
            self.spikes += 1
            ms += self.cfg.spike_ms
        return ms

    def tick_wave(self, wave: int) -> None:
        """on_wave hook: advance simulated time by one wave's cost."""
        self.clock.advance(self.wave_ms())

    def shard_fault(self, shard: int, attempt: int) -> None:
        """fault hook for ``search_with_retry``."""
        from repro.core.distributed_ivf import ShardFault
        if self.rng.random() < self.cfg.shard_fault_rate:
            self.shard_faults += 1
            raise ShardFault(
                f"chaos: shard {shard} fault (attempt {attempt})")


# ---------------------------------------------------------------------------
# drill 1: crash + WAL recovery over a live mutation stream
# ---------------------------------------------------------------------------

def _mutation_stream(cfg: ChaosConfig, docs: np.ndarray):
    """Deterministic (op, payload) list: adds of noisy corpus copies,
    deletes of previously added ids, periodic merges."""
    rng = np.random.default_rng(cfg.seed + 1)
    ops = []
    for step in range(cfg.mutation_steps):
        src = rng.integers(0, docs.shape[0], cfg.adds_per_step)
        noise = rng.normal(scale=0.05,
                           size=(cfg.adds_per_step, docs.shape[1]))
        ops.append(("add", (docs[src] + noise).astype(np.float32)))
        if step % 3 == 2:
            ops.append(("delete_recent", int(cfg.adds_per_step // 2)))
        if step % 6 == 5:
            ops.append(("merge", None))
    return ops


def _apply(live, op, payload, added: List[int]):
    from repro.index import DeltaFull
    if op == "add":
        try:
            added.extend(int(i) for i in live.add(payload))
        except DeltaFull:
            live.merge_delta()
            added.extend(int(i) for i in live.add(payload))
    elif op == "delete_recent":
        if len(added) >= payload:
            doomed = [added.pop() for _ in range(payload)]
            live.delete(doomed)
    else:
        live.merge_delta()


def run_crash_recovery(index, docs: np.ndarray, queries: np.ndarray,
                       cfg: ChaosConfig, workdir: str, *, k: int = 10,
                       n_probe: int = 16) -> Dict:
    """Kill-and-replay drill.  Returns recovery metrics including the
    bit-identity verdict against an uncrashed oracle."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core import policies, search
    from repro.index import (IndexRegistry, LiveIndex, MutationWAL,
                             version_of)

    # group commit on: durability batched across mutations, forced at
    # merge/snapshot boundaries — the drill proves recovery semantics
    # (torn tail, replay, bit-identity) are unchanged under batching
    wal = MutationWAL(os.path.join(workdir, "mutations.wal"),
                      group_commit_n=8, group_commit_ms=50.0)
    live = LiveIndex(index, delta_cap=4096, wal=wal)
    oracle = LiveIndex(index, delta_cap=4096)
    mgr = CheckpointManager(os.path.join(workdir, "snapshots"),
                            async_save=False, keep=2)
    reg = IndexRegistry(version_of(live))
    reg.save(mgr)                      # base snapshot (seq 0)

    crashes = 0
    recovery_ms: List[float] = []
    replayed = 0
    added_live: List[int] = []
    added_oracle: List[int] = []
    ops = _mutation_stream(cfg, docs)
    for step, (op, payload) in enumerate(ops):
        _apply(live, op, payload, added_live)
        _apply(oracle, op, payload, added_oracle)
        if cfg.crash_every and (step + 1) % cfg.crash_every == 0:
            crashes += 1
            try:
                raise SimulatedFailure(f"chaos crash @ boundary {step}")
            except SimulatedFailure:
                pass                   # process "dies" here
            t0 = time.monotonic()
            _, live, rep = IndexRegistry.recover(mgr, wal)
            recovery_ms.append((time.monotonic() - t0) * 1000.0)
            replayed += rep.applied
        if cfg.snapshot_every and (step + 1) % cfg.snapshot_every == 0:
            wal.flush()                # snapshot must not outrun the log
            reg = IndexRegistry(version_of(live))
            reg.save(mgr)
            wal.truncate_upto(live.seq)

    # bit-identity: recovered-and-continued live vs uncrashed oracle,
    # on both kernel paths
    q = jnp.asarray(queries)
    identical = True
    for kw in ({}, {"use_fused_kernel": True, "chunk": 4}):
        pol = policies.patience(n_probe, delta=2, phi=90.0, k=k, tau=3)
        a = live.search(q, pol, **kw)
        b = oracle.search(q, pol, **kw)
        identical &= bool(
            np.array_equal(np.asarray(a.topk_ids),
                           np.asarray(b.topk_ids))
            and np.array_equal(np.asarray(a.probes),
                               np.asarray(b.probes))
            and np.allclose(np.asarray(a.phi_hist),
                            np.asarray(b.phi_hist), atol=1e-4))
    wal.close()
    return {
        "crashes": crashes,
        "mutations": len(ops),
        "replayed_records": replayed,
        "mean_recovery_ms": float(np.mean(recovery_ms))
        if recovery_ms else 0.0,
        "max_recovery_ms": float(np.max(recovery_ms))
        if recovery_ms else 0.0,
        "final_seq": live.seq,
        "bit_identical": identical,
    }


# ---------------------------------------------------------------------------
# drill 2: recall-vs-deadline curve under latency spikes
# ---------------------------------------------------------------------------

def run_deadline_sweep(index, queries: np.ndarray,
                       exact_ids: np.ndarray, cfg: ChaosConfig,
                       deadlines_ms: List[float], *, k: int = 10,
                       n_probe: int = 16, delta: int = 3,
                       phi: float = 90.0, wave_size: int = 32,
                       chunk: int = 1) -> List[Dict]:
    from repro.core import metrics
    from repro.core.serving import WaveScheduler

    curve = []
    for dl in list(deadlines_ms) + [None]:     # None = no deadline row
        monkey = ChaosMonkey(cfg)              # fresh RNG per point
        ws = WaveScheduler(index, wave_size=wave_size, chunk=chunk,
                           k=k, n_probe=n_probe, delta=delta, phi=phi,
                           deadline_ms=dl, clock=monkey.clock)
        rep = ws.serve(queries, on_wave=monkey.tick_wave)
        nq = queries.shape[0]
        ids = np.stack([rep.results[i] for i in range(nq)])
        over = [rep.latency_ms[i] - dl for i in range(nq)
                if dl is not None and rep.latency_ms[i] > dl]
        reasons: Dict[str, int] = {}
        for r in rep.degraded.values():
            reasons[r] = reasons.get(r, 0) + 1
        curve.append({
            "deadline_ms": dl,
            "recall": round(metrics.r_star_at_k(ids, exact_ids), 4),
            "degraded_fraction": round(rep.degraded_fraction, 4),
            "reasons": reasons,
            "max_overshoot_ms": round(max(over, default=0.0), 3),
            "wave_cost_ms": round(rep.wave_cost_ms, 3),
            "waves": rep.waves,
            "spikes": monkey.spikes,
        })
    return curve


# ---------------------------------------------------------------------------
# drill 3: shard faults through the retry/backoff data plane
# ---------------------------------------------------------------------------

def run_shard_drill(index, queries: np.ndarray, exact_ids: np.ndarray,
                    cfg: ChaosConfig, *, k: int = 10,
                    n_probe: int = 16) -> Dict:
    from repro.core import metrics
    from repro.core.distributed_ivf import search_with_retry, shard_index
    from repro.runtime.straggler import RetryPolicy

    monkey = ChaosMonkey(cfg)
    sh = shard_index(index, cfg.n_shards)
    sleep_log = {"ms": 0.0}

    def sim_sleep(ms: float) -> None:
        sleep_log["ms"] += ms
        monkey.clock.advance(ms)

    _, ids_clean, _ = search_with_retry(
        sh, queries, k=k, n_probe=n_probe, sleep=sim_sleep)
    _, ids_chaos, rep = search_with_retry(
        sh, queries, k=k, n_probe=n_probe,
        retry=RetryPolicy(max_retries=3, base_ms=1.0),
        fault=monkey.shard_fault, sleep=sim_sleep)
    return {
        "n_shards": cfg.n_shards,
        "fault_rate": cfg.shard_fault_rate,
        "injected_faults": monkey.shard_faults,
        "attempts": rep.attempts,
        "retries": rep.retries,
        "skipped_shards": rep.skipped_shards,
        "lost_clusters": rep.lost_clusters,
        "backoff_ms": round(rep.backoff_ms, 3),
        "recall_clean": round(
            metrics.r_star_at_k(np.asarray(ids_clean), exact_ids), 4),
        "recall_chaos": round(
            metrics.r_star_at_k(np.asarray(ids_chaos), exact_ids), 4),
    }


# ---------------------------------------------------------------------------

def run_chaos(index, docs: np.ndarray, queries: np.ndarray,
              exact_ids: np.ndarray, cfg: ChaosConfig, workdir: str, *,
              k: int = 10, n_probe: int = 16,
              deadlines_ms: Optional[List[float]] = None) -> Dict:
    """All three drills; the returned dict is the
    ``BENCH_resilience.json`` payload."""
    deadlines_ms = deadlines_ms or [2.0, 5.0, 10.0, 25.0]
    t0 = time.monotonic()
    out = {
        "config": dataclasses.asdict(cfg),
        "recovery": run_crash_recovery(index, docs, queries, cfg,
                                       workdir, k=k, n_probe=n_probe),
        "deadline_curve": run_deadline_sweep(index, queries, exact_ids,
                                             cfg, deadlines_ms, k=k,
                                             n_probe=n_probe),
        "shard_faults": run_shard_drill(index, queries, exact_ids, cfg,
                                        k=k, n_probe=n_probe),
    }
    out["wall_s"] = round(time.monotonic() - t0, 1)
    return out
