"""Straggler mitigation for the serving path (DESIGN §5).

A serving *wave* fans a query batch over shards; a shard missing the
deadline gets its slice *re-dispatched* to the fastest shard of the next
wave (speculative retry), bounding p99 by ~2 wave times rather than the
slowest shard.  ``run_waves`` simulates that control-plane policy; the
:class:`RetryPolicy` backoff schedule defined here is shared with the
*real* data plane (``repro.core.distributed_ivf.search_with_retry``),
where a faulting shard probe is retried with exponential backoff and
finally skipped so the wave degrades instead of dying.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class RetryPolicy:
    """Backoff schedule for shard probe retries.

    ``jitter="none"`` (default) keeps the classic deterministic
    exponential schedule.  ``jitter="decorrelated"`` switches
    :meth:`next_backoff` to decorrelated jitter — each sleep is drawn
    uniformly from ``[base_ms, 3 * previous_sleep]`` (capped at
    ``max_ms``) — which de-synchronises retry storms: when a shard
    fault hits many queries at once, deterministic backoff re-dispatches
    them all on the same beat, re-spiking the shard, while decorrelated
    draws spread the herd across the window.

    ``budget_ms`` is a PER-QUERY cap on *total* backoff sleep across
    all shards: once a query has burned its budget waiting, a faulting
    shard is skipped immediately (lost clusters accounted, rung
    "budget") instead of waiting out more retries — total stall is
    bounded even when every shard is sick.  ``inf`` (default) keeps
    pre-budget behavior.
    """
    max_retries: int = 3         # attempts = max_retries + 1
    base_ms: float = 1.0
    multiplier: float = 2.0
    max_ms: float = 1000.0
    jitter: str = "none"         # "none" | "decorrelated"
    budget_ms: float = float("inf")

    def __post_init__(self):
        if self.max_retries < 0 or self.base_ms < 0 \
                or self.multiplier < 1.0:
            raise ValueError(
                f"invalid RetryPolicy(max_retries={self.max_retries}, "
                f"base_ms={self.base_ms}, multiplier={self.multiplier})")
        if self.jitter not in ("none", "decorrelated"):
            raise ValueError(
                f"jitter must be 'none' or 'decorrelated', got "
                f"{self.jitter!r}")
        if self.budget_ms <= 0:
            raise ValueError(
                f"budget_ms must be positive (use inf for unbounded), "
                f"got {self.budget_ms}")

    def backoff_ms(self, attempt: int) -> float:
        """Deterministic backoff before retry ``attempt`` (0-based
        first retry) — the ``jitter="none"`` schedule."""
        return min(self.base_ms * self.multiplier ** attempt,
                   self.max_ms)

    def next_backoff(self, attempt: int, prev_ms: float,
                     rng: Optional[np.random.Generator] = None) -> float:
        """Backoff before retry ``attempt`` given the previous sleep.

        With ``jitter="none"`` this is exactly :meth:`backoff_ms`
        (``prev_ms``/``rng`` ignored), so existing deterministic
        callers and tests are unchanged.  With
        ``jitter="decorrelated"`` it draws uniform
        ``[base_ms, 3 * prev_ms]`` (AWS decorrelated jitter), seeded
        by the caller's ``rng``; ``prev_ms <= 0`` (first retry) starts
        the chain at ``base_ms``.
        """
        if self.jitter == "none":
            return self.backoff_ms(attempt)
        if rng is None:
            rng = np.random.default_rng(0)
        lo = self.base_ms
        hi = max(lo, 3.0 * (prev_ms if prev_ms > 0 else lo))
        return min(float(rng.uniform(lo, hi)), self.max_ms)


@dataclass
class WaveStats:
    waves: int = 0
    redispatches: int = 0
    completed: int = 0
    pending: int = 0             # queries still unserved at max_waves
    p50_ms: float = 0.0
    p99_ms: float = 0.0


def run_waves(n_queries: int, n_shards: int,
              latency_sampler: Callable[[np.random.Generator, int], float],
              *, deadline_ms: float, wave_size: int, seed: int = 0,
              max_waves: int = 10_000) -> WaveStats:
    """Simulate wave dispatch with straggler re-dispatch.

    latency_sampler(rng, shard) -> per-wave shard latency (ms). A query
    slice completes when some wave's owning shard meets the deadline.
    """
    rng = np.random.default_rng(seed)
    pending = list(range(n_queries))
    done_at: Dict[int, float] = {}
    t = 0.0
    stats = WaveStats()
    while pending and stats.waves < max_waves:
        wave = pending[: wave_size * n_shards]
        pending = pending[wave_size * n_shards:]
        slices = np.array_split(np.asarray(wave), n_shards)
        lat = np.array([latency_sampler(rng, s) for s in range(n_shards)])
        wave_t = min(np.max(lat), deadline_ms)
        for s, sl in enumerate(slices):
            if lat[s] <= deadline_ms:
                for q in sl:
                    done_at[q] = t + lat[s]
            else:
                stats.redispatches += len(sl)
                pending = list(sl) + pending     # retry first, next wave
        t += wave_t
        stats.waves += 1
    lats = np.array(list(done_at.values()))
    stats.completed = len(done_at)
    # queries still pending when max_waves ran out would otherwise
    # silently vanish from the completion stats — surface them
    stats.pending = len(pending)
    if len(lats):
        stats.p50_ms = float(np.percentile(lats, 50))
        stats.p99_ms = float(np.percentile(lats, 99))
    return stats
