"""Straggler mitigation for the serving path (DESIGN §5).

A serving *wave* fans a query batch over shards; a shard missing the
deadline gets its slice *re-dispatched* to the fastest shard of the next
wave (speculative retry), bounding p99 by ~2 wave times rather than the
slowest shard.  ``run_waves`` simulates that control-plane policy; the
:class:`RetryPolicy` backoff schedule defined here is shared with the
*real* data plane (``repro.core.distributed_ivf.search_with_retry``),
where a faulting shard probe is retried with exponential backoff and
finally skipped so the wave degrades instead of dying.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff schedule for shard probe retries."""
    max_retries: int = 3         # attempts = max_retries + 1
    base_ms: float = 1.0
    multiplier: float = 2.0
    max_ms: float = 1000.0

    def __post_init__(self):
        if self.max_retries < 0 or self.base_ms < 0 \
                or self.multiplier < 1.0:
            raise ValueError(
                f"invalid RetryPolicy(max_retries={self.max_retries}, "
                f"base_ms={self.base_ms}, multiplier={self.multiplier})")

    def backoff_ms(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (0-based first retry)."""
        return min(self.base_ms * self.multiplier ** attempt,
                   self.max_ms)


@dataclass
class WaveStats:
    waves: int = 0
    redispatches: int = 0
    completed: int = 0
    pending: int = 0             # queries still unserved at max_waves
    p50_ms: float = 0.0
    p99_ms: float = 0.0


def run_waves(n_queries: int, n_shards: int,
              latency_sampler: Callable[[np.random.Generator, int], float],
              *, deadline_ms: float, wave_size: int, seed: int = 0,
              max_waves: int = 10_000) -> WaveStats:
    """Simulate wave dispatch with straggler re-dispatch.

    latency_sampler(rng, shard) -> per-wave shard latency (ms). A query
    slice completes when some wave's owning shard meets the deadline.
    """
    rng = np.random.default_rng(seed)
    pending = list(range(n_queries))
    done_at: Dict[int, float] = {}
    t = 0.0
    stats = WaveStats()
    while pending and stats.waves < max_waves:
        wave = pending[: wave_size * n_shards]
        pending = pending[wave_size * n_shards:]
        slices = np.array_split(np.asarray(wave), n_shards)
        lat = np.array([latency_sampler(rng, s) for s in range(n_shards)])
        wave_t = min(np.max(lat), deadline_ms)
        for s, sl in enumerate(slices):
            if lat[s] <= deadline_ms:
                for q in sl:
                    done_at[q] = t + lat[s]
            else:
                stats.redispatches += len(sl)
                pending = list(sl) + pending     # retry first, next wave
        t += wave_t
        stats.waves += 1
    lats = np.array(list(done_at.values()))
    stats.completed = len(done_at)
    # queries still pending when max_waves ran out would otherwise
    # silently vanish from the completion stats — surface them
    stats.pending = len(pending)
    if len(lats):
        stats.p50_ms = float(np.percentile(lats, 50))
        stats.p99_ms = float(np.percentile(lats, 99))
    return stats
