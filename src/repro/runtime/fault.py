"""Fault-tolerant training driver: checkpoint/restart + deterministic
replay. Failures (node loss, preemption) surface as exceptions from the
step function; the driver restores the latest checkpoint and replays the
deterministic data stream from the restored step (bitwise-identical
trajectory — tests/test_fault.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DeterministicBatcher

Pytree = Any


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests / chaos drills)."""


@dataclass
class TrainerReport:
    losses: List[float] = field(default_factory=list)
    steps_run: int = 0
    restarts: int = 0
    wall_s: float = 0.0


class FaultTolerantTrainer:
    """step_fn(state, batch) -> (state, loss). state is any pytree
    (params + opt state + step counter live inside)."""

    def __init__(self, step_fn: Callable, init_state: Pytree,
                 batcher: DeterministicBatcher, ckpt: CheckpointManager,
                 *, ckpt_every: int = 10):
        self.step_fn = step_fn
        self.init_state = init_state
        self.batcher = batcher
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every

    def _restore_or_init(self) -> Tuple[int, Pytree]:
        if self.ckpt.latest_step() is not None:
            return self.ckpt.restore(self.init_state)
        return 0, self.init_state

    def run(self, n_steps: int, *,
            fail_at: Optional[Dict[int, int]] = None,
            max_restarts: int = 8) -> TrainerReport:
        """fail_at: {global_step: times} -> raise SimulatedFailure that
        many times when reaching the step (before it completes)."""
        report = TrainerReport()
        fail_budget = dict(fail_at or {})
        t0 = time.time()
        restarts = 0
        while True:
            start, state = self._restore_or_init()
            try:
                for step in range(start, n_steps):
                    if fail_budget.get(step, 0) > 0:
                        fail_budget[step] -= 1
                        raise SimulatedFailure(f"injected @ step {step}")
                    batch = self.batcher.batch(step)
                    state, loss = self.step_fn(state, batch)
                    report.losses.append(float(loss))
                    report.steps_run += 1
                    if (step + 1) % self.ckpt_every == 0:
                        self.ckpt.save(step + 1, state)
                break
            except SimulatedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                # truncate the loss log to the restore point so the
                # reported trajectory matches what a fresh run would see
                restored = self.ckpt.latest_step() or 0
                report.losses = report.losses[:restored]
        self.ckpt.wait()
        report.restarts = restarts
        report.wall_s = time.time() - t0
        return report
