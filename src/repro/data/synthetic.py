"""Synthetic data generators (DESIGN §6 — simulated data gates).

``clustered_corpus`` replaces the MS-MARCO + {STAR, Contriever, TAS-B}
embedding collections: an anisotropic Gaussian mixture with power-law
component sizes. Queries mix *easy* (noisy copies of docs — the ~50% of
queries whose 1-NN sits in the first probed cluster) and *hard*
(interpolations between components — the long power-law tail). The
"encoder" knob ``spread`` emulates harder encoders (Contriever/TAS-B
need larger N in the paper).

Also: LM token streams, zipf click logs (recsys), random graphs (GNN).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass
class Corpus:
    docs: np.ndarray       # (n_docs, dim) f32, L2-normalised
    queries: np.ndarray    # (n_q, dim)
    relevant: np.ndarray   # (n_q,) int32 — "human label" doc per query


def clustered_corpus(n_docs: int = 100_000, dim: int = 128,
                     n_components: int = 512, n_queries: int = 4096,
                     *, spread: float = 0.25, hard_frac: float = 0.35,
                     seed: int = 0) -> Corpus:
    rng = np.random.default_rng(seed)
    # power-law component sizes (Zipf s=1.1)
    w = 1.0 / np.arange(1, n_components + 1) ** 1.1
    w /= w.sum()
    sizes = rng.multinomial(n_docs, w)
    centers = rng.normal(0, 1, (n_components, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    scales = (0.5 + rng.random(n_components)) * spread
    docs = np.empty((n_docs, dim), np.float32)
    comp_of = np.empty(n_docs, np.int32)
    pos = 0
    for c, s in enumerate(sizes):
        if s == 0:
            continue
        pts = centers[c] + rng.normal(0, scales[c], (s, dim))
        docs[pos: pos + s] = pts
        comp_of[pos: pos + s] = c
        pos += s
    docs /= np.linalg.norm(docs, axis=1, keepdims=True)

    n_hard = int(n_queries * hard_frac)
    n_easy = n_queries - n_hard
    # easy: perturbed docs (1-NN almost surely in the home cluster)
    src = rng.integers(0, n_docs, n_easy)
    easy = docs[src] + rng.normal(0, 0.15 * spread, (n_easy, dim))
    # hard: interpolations between two components + noise
    c1 = rng.integers(0, n_components, n_hard)
    c2 = rng.integers(0, n_components, n_hard)
    t = rng.random((n_hard, 1)).astype(np.float32)
    hard = centers[c1] * t + centers[c2] * (1 - t) + \
        rng.normal(0, spread, (n_hard, dim))
    queries = np.concatenate([easy, hard]).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)
    perm = rng.permutation(n_queries)
    queries = queries[perm]
    # "relevant" doc = exact 1-NN of a noisy variant (proxy for qrels)
    relevant = np.empty(n_queries, np.int32)
    block = 256
    for s in range(0, n_queries, block):
        e = min(s + block, n_queries)
        sims = queries[s:e] @ docs.T
        relevant[s:e] = np.argmax(sims, 1)
    return Corpus(docs, queries, relevant)


# ---------------------------------------------------------------------------
# LM / recsys / graph generators
# ---------------------------------------------------------------------------


def token_stream(n_tokens: int, vocab: int, seed: int = 0,
                 zipf_s: float = 1.2) -> np.ndarray:
    """Zipf-distributed token ids (realistic embedding-gather skew)."""
    rng = np.random.default_rng(seed)
    ranks = rng.zipf(zipf_s, n_tokens)
    return np.minimum(ranks - 1, vocab - 1).astype(np.int32)


def click_log(batch: int, n_dense: int, n_sparse: int, rows_per_field: int,
              seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    dense = rng.normal(0, 1, (batch, max(n_dense, 1))).astype(np.float32)
    ranks = rng.zipf(1.2, (batch, n_sparse))
    sparse = np.minimum(ranks - 1, rows_per_field - 1).astype(np.int32)
    # click prob depends on a random linear model over fields (learnable)
    logits = 0.1 * dense.sum(1) + 0.01 * (sparse % 17).sum(1) - 1.0
    y = (rng.random(batch) < 1 / (1 + np.exp(-logits))).astype(np.float32)
    if n_dense == 0:
        dense = np.zeros((batch, 0), np.float32)
    return {"dense": dense, "sparse": sparse, "label": y}


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 seed: int = 0, power_law: bool = True
                 ) -> Dict[str, np.ndarray]:
    """Random (power-law degree) graph with community-correlated labels."""
    rng = np.random.default_rng(seed)
    if power_law:
        p = 1.0 / np.arange(1, n_nodes + 1) ** 0.8
        p /= p.sum()
        src = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    else:
        src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    comm = rng.integers(0, n_classes, n_nodes)
    feats = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    feats[:, : n_classes] += 2.0 * np.eye(n_classes, dtype=np.float32)[comm]
    labels = comm.astype(np.int32)
    return {"edge_src": src, "edge_dst": dst, "feat": feats,
            "label": labels}
