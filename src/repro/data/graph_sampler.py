"""Layered (fanout) neighbor sampling for minibatch GNN training.

GraphSAGE-style blocks with the DGL convention: each block's *output*
(dst) nodes are a prefix of its *input* (src) node array, so layer i's
activations are rows [0, n_out) of the aggregation over block i. Blocks
are padded to static shapes so the jitted train step never retraces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray    # (N+1,)
    indices: np.ndarray   # (E,) in-neighbor (src) per incoming edge
    n_nodes: int

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray,
                   n_nodes: int) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")
        s, d = src[order], dst[order]
        counts = np.bincount(d, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        indptr[1:] = np.cumsum(counts)
        return cls(indptr, s.astype(np.int32), n_nodes)


@dataclass
class Block:
    """Bipartite sampled layer. src/dst index into ``nodes``; dst nodes
    are nodes[:n_out]."""
    edge_src: np.ndarray   # (E_pad,) int32 positions into nodes
    edge_dst: np.ndarray   # (E_pad,) int32 positions into nodes[:n_out]
    edge_mask: np.ndarray  # (E_pad,) bool
    nodes: np.ndarray      # (N_pad,) int32 global node ids (dst prefix)
    n_out: int


def sample_blocks(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                  rng: np.random.Generator) -> List[Block]:
    """Returns blocks outermost-first (blocks[0] feeds the final layer).
    blocks[-1].nodes is the full input node set (layer-0 features)."""
    blocks: List[Block] = []
    cur = np.asarray(seeds, np.int32)
    for f in fanouts:
        n_dst = cur.shape[0]
        e_pad = n_dst * f
        src_g = np.zeros(e_pad, np.int32)   # global src ids
        dst_p = np.zeros(e_pad, np.int32)   # dst position (into cur)
        mask = np.zeros(e_pad, bool)
        for i, v in enumerate(cur):
            lo, hi = g.indptr[v], g.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = g.indices[lo + rng.choice(deg, take,
                                              replace=bool(deg < take))]
            src_g[i * f: i * f + take] = picks
            dst_p[i * f: i * f + take] = i
            mask[i * f: i * f + take] = True
        extra = np.setdiff1d(src_g[mask], cur)
        nodes = np.concatenate([cur, extra]).astype(np.int32)
        # map global src ids -> positions in nodes
        order = np.argsort(nodes, kind="stable")
        pos_sorted = np.searchsorted(nodes[order], src_g)
        src_p = order[np.clip(pos_sorted, 0, nodes.size - 1)].astype(
            np.int32)
        src_p[~mask] = 0
        blocks.append(Block(src_p, dst_p, mask, nodes, n_dst))
        cur = nodes
    return blocks


def pad_block(b: Block, e_pad: int, n_pad: int) -> Block:
    def pade(a, fill=0):
        out = np.full(e_pad, fill, a.dtype)
        out[: a.shape[0]] = a
        return out

    nodes = np.zeros(n_pad, np.int32)
    nodes[: b.nodes.shape[0]] = b.nodes
    return Block(pade(b.edge_src), pade(b.edge_dst),
                 pade(b.edge_mask, False), nodes, b.n_out)


def block_shapes(batch_nodes: int, fanouts: Sequence[int]
                 ) -> List[Tuple[int, int, int]]:
    """Static (e_pad, n_pad, n_out) per block, outermost-first."""
    out = []
    n_dst = batch_nodes
    for f in fanouts:
        e_pad = n_dst * f
        n_pad = n_dst + e_pad           # worst case: all srcs distinct
        out.append((e_pad, n_pad, n_dst))
        n_dst = n_pad
    return out
