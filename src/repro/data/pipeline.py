"""Deterministic, restart-safe input pipelines.

Every batch is a pure function of (seed, step) — after a fault-restart
the pipeline replays the identical stream (tested in tests/test_fault.py).
A background prefetch thread overlaps host batch synthesis with device
compute, the standard TPU input pattern.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class DeterministicBatcher:
    """batch(step) = f(seed, step); stateless between calls."""

    def __init__(self, make_batch: Callable[[np.random.Generator], Dict],
                 seed: int = 0):
        self.make_batch = make_batch
        self.seed = seed

    def batch(self, step: int) -> Dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        return self.make_batch(rng)


def lm_batcher(vocab: int, batch: int, seq: int, seed: int = 0
               ) -> DeterministicBatcher:
    def mk(rng: np.random.Generator) -> Dict:
        ranks = rng.zipf(1.2, (batch, seq + 1))
        toks = np.minimum(ranks - 1, vocab - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return DeterministicBatcher(mk, seed)


def recsys_batcher(n_dense: int, n_sparse: int, rows_per_field: int,
                   batch: int, seed: int = 0) -> DeterministicBatcher:
    from repro.data.synthetic import click_log

    def mk(rng: np.random.Generator) -> Dict:
        s = int(rng.integers(0, 2 ** 31 - 1))
        return click_log(batch, n_dense, n_sparse, rows_per_field, seed=s)
    return DeterministicBatcher(mk, seed)


def pair_batcher(corpus_docs: np.ndarray, batch: int, noise: float = 0.1,
                 seed: int = 0) -> DeterministicBatcher:
    """Contrastive (query, positive-doc) pairs for retriever training."""
    n, d = corpus_docs.shape

    def mk(rng: np.random.Generator) -> Dict:
        idx = rng.integers(0, n, batch)
        pos = corpus_docs[idx]
        q = pos + rng.normal(0, noise, pos.shape).astype(np.float32)
        return {"query": q.astype(np.float32), "doc": pos,
                "doc_id": idx.astype(np.int32)}
    return DeterministicBatcher(mk, seed)


class Prefetcher:
    """Background-thread prefetch of the deterministic stream."""

    def __init__(self, batcher: DeterministicBatcher, start_step: int,
                 depth: int = 2):
        self.batcher = batcher
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            b = self.batcher.batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
