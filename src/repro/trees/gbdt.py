"""Histogram gradient-boosted trees (numpy) — the LightGBM stand-in.

The paper trains "small additive forests of 100 trees using LightGBM";
LightGBM is not available offline, so we implement the same algorithm
class: quantile-binned histograms, level-wise growth, L2 / logistic
objectives, instance weights (the classifier's Exit-class weight ``w``),
and early stopping on a validation set. Inference runs in JAX via
``repro.trees.jax_infer``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class Tree:
    feat: np.ndarray     # (M,) int32; -1 = leaf
    thresh: np.ndarray   # (M,) f32 raw-unit threshold, go left if x <= thr
    left: np.ndarray     # (M,) int32
    right: np.ndarray    # (M,) int32
    value: np.ndarray    # (M,) f32; nonzero only at leaves


@dataclass
class Forest:
    trees: List[Tree]
    base: float
    best_iteration: int = -1


def _sigmoid(z: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-np.clip(z, -30, 30)))


def _bin_data(x: np.ndarray, n_bins: int
              ) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Quantile binning. Returns (binned uint16 (N,F), edges per feature)."""
    n, f = x.shape
    sample = x if n <= 50_000 else x[np.random.default_rng(0).choice(
        n, 50_000, replace=False)]
    binned = np.empty((n, f), np.uint16)
    edges: List[np.ndarray] = []
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    for j in range(f):
        e = np.unique(np.quantile(sample[:, j], qs))
        e = e[np.isfinite(e)]
        edges.append(e.astype(np.float32))
        binned[:, j] = np.searchsorted(e, x[:, j], side="left").astype(np.uint16)
    return binned, edges


class GBDT:
    """Level-wise histogram GBDT. objective: 'l2' | 'logistic'."""

    def __init__(self, objective: str = "l2", n_trees: int = 100,
                 learning_rate: float = 0.1, max_depth: int = 6,
                 n_bins: int = 64, reg_lambda: float = 1.0,
                 min_child_weight: float = 1.0, min_gain: float = 1e-6,
                 early_stopping: int = 10, seed: int = 0,
                 colsample: float = 1.0):
        assert objective in ("l2", "logistic")
        self.objective = objective
        self.n_trees = n_trees
        self.lr = learning_rate
        self.max_depth = max_depth
        self.n_bins = n_bins
        self.reg_lambda = reg_lambda
        self.min_child_weight = min_child_weight
        self.min_gain = min_gain
        self.early_stopping = early_stopping
        self.seed = seed
        self.colsample = colsample

    # -- objective ---------------------------------------------------------
    def _init_base(self, y, w):
        mean = float(np.average(y, weights=w))
        if self.objective == "logistic":
            mean = min(max(mean, 1e-6), 1 - 1e-6)
            return float(np.log(mean / (1 - mean)))
        return mean

    def _grad_hess(self, margin, y, w):
        if self.objective == "logistic":
            p = _sigmoid(margin)
            return (p - y) * w, np.maximum(p * (1 - p), 1e-6) * w
        return (margin - y) * w, w.copy()

    def _loss(self, margin, y, w):
        if self.objective == "logistic":
            p = _sigmoid(margin)
            ll = y * np.log(np.clip(p, 1e-9, 1)) + \
                (1 - y) * np.log(np.clip(1 - p, 1e-9, 1))
            return float(-np.average(ll, weights=w))
        return float(np.average((margin - y) ** 2, weights=w))

    # -- training ----------------------------------------------------------
    def fit(self, x: np.ndarray, y: np.ndarray,
            sample_weight: Optional[np.ndarray] = None,
            eval_set: Optional[Tuple[np.ndarray, np.ndarray]] = None
            ) -> Forest:
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float64)
        n, f = x.shape
        w = np.ones(n) if sample_weight is None else \
            np.asarray(sample_weight, np.float64)
        rng = np.random.default_rng(self.seed)
        binned, edges = _bin_data(x, self.n_bins)
        base = self._init_base(y, w)
        margin = np.full(n, base)
        trees: List[Tree] = []
        ev = None
        if eval_set is not None:
            ev_x = np.asarray(eval_set[0], np.float32)
            ev_y = np.asarray(eval_set[1], np.float64)
            ev_margin = np.full(ev_x.shape[0], base)
            ev_w = np.ones(ev_x.shape[0])
            best_loss, best_iter, since = np.inf, -1, 0
            ev = True
        for it in range(self.n_trees):
            g, h = self._grad_hess(margin, y, w)
            cols = np.arange(f) if self.colsample >= 1.0 else \
                np.sort(rng.choice(f, max(1, int(f * self.colsample)),
                                   replace=False))
            tree = self._build_tree(binned, edges, g, h, cols)
            trees.append(tree)
            margin += _predict_tree(tree, x)
            if ev:
                ev_margin += _predict_tree(tree, ev_x)
                loss = self._loss(ev_margin, ev_y, ev_w)
                if loss < best_loss - 1e-9:
                    best_loss, best_iter, since = loss, it, 0
                else:
                    since += 1
                    if since >= self.early_stopping:
                        trees = trees[: best_iter + 1]
                        return Forest(trees, base, best_iter)
        return Forest(trees, base, len(trees) - 1)

    def _build_tree(self, binned, edges, g, h, cols) -> Tree:
        n = binned.shape[0]
        nb = self.n_bins
        max_nodes = 2 ** (self.max_depth + 1) - 1
        feat = np.full(max_nodes, -1, np.int32)
        thresh = np.zeros(max_nodes, np.float32)
        thresh_bin = np.zeros(max_nodes, np.int32)
        left = np.zeros(max_nodes, np.int32)
        right = np.zeros(max_nodes, np.int32)
        value = np.zeros(max_nodes, np.float32)
        node_of = np.zeros(n, np.int32)      # heap index per sample
        settled = np.zeros(n, bool)          # sample reached a leaf

        for depth in range(self.max_depth):
            level_off = 2 ** depth - 1
            n_level = 2 ** depth
            act = ~settled
            if not act.any():
                break
            rel = node_of[act] - level_off
            g_a, h_a = g[act], h[act]
            # totals per node
            gtot = np.bincount(rel, weights=g_a, minlength=n_level)
            htot = np.bincount(rel, weights=h_a, minlength=n_level)
            best_gain = np.full(n_level, 0.0)
            best_feat = np.full(n_level, -1, np.int32)
            best_bin = np.zeros(n_level, np.int32)
            lam = self.reg_lambda
            parent_score = gtot ** 2 / (htot + lam)
            for j in cols:
                if len(edges[j]) == 0:
                    continue
                idx = rel * nb + binned[act, j]
                hg = np.bincount(idx, weights=g_a, minlength=n_level * nb
                                 ).reshape(n_level, nb)
                hh = np.bincount(idx, weights=h_a, minlength=n_level * nb
                                 ).reshape(n_level, nb)
                gl = np.cumsum(hg, 1)[:, :-1]
                hl = np.cumsum(hh, 1)[:, :-1]
                gr = gtot[:, None] - gl
                hr = htot[:, None] - hl
                ok = (hl >= self.min_child_weight) & \
                     (hr >= self.min_child_weight)
                gain = np.where(
                    ok, gl ** 2 / (hl + lam) + gr ** 2 / (hr + lam)
                    - parent_score[:, None], -np.inf)
                jbest = np.argmax(gain, 1)
                jgain = gain[np.arange(n_level), jbest]
                upd = jgain > best_gain
                best_gain = np.where(upd, jgain, best_gain)
                best_feat = np.where(upd, j, best_feat)
                best_bin = np.where(upd, jbest, best_bin)
            for r in range(n_level):
                node = level_off + r
                if htot[r] <= 0:
                    continue
                if best_feat[r] < 0 or best_gain[r] <= self.min_gain:
                    value[node] = -self.lr * gtot[r] / (htot[r] + lam)
                    sel = act & (node_of == node)
                    settled[sel] = True
                    continue
                j, b = int(best_feat[r]), int(best_bin[r])
                feat[node] = j
                thresh_bin[node] = b
                e = edges[j]
                thresh[node] = e[min(b, len(e) - 1)]
                left[node] = 2 * node + 1
                right[node] = 2 * node + 2
                sel = act & (node_of == node)
                goes_left = binned[sel, j] <= b
                child = np.where(goes_left, 2 * node + 1, 2 * node + 2)
                node_of[sel] = child
        # terminal level leaves
        act = ~settled
        if act.any():
            lam = self.reg_lambda
            for node in np.unique(node_of[act]):
                sel = act & (node_of == node)
                gg, hh_ = g[sel].sum(), h[sel].sum()
                value[node] = -self.lr * gg / (hh_ + lam)
        used = max_nodes
        return Tree(feat[:used], thresh[:used], left[:used], right[:used],
                    value[:used])

    def predict_margin(self, forest: Forest, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        out = np.full(x.shape[0], forest.base)
        for t in forest.trees:
            out += _predict_tree(t, x)
        return out

    def predict(self, forest: Forest, x: np.ndarray) -> np.ndarray:
        m = self.predict_margin(forest, x)
        return _sigmoid(m) if self.objective == "logistic" else m


def _predict_tree(tree: Tree, x: np.ndarray) -> np.ndarray:
    n = x.shape[0]
    node = np.zeros(n, np.int32)
    for _ in range(32):  # depth bound
        f = tree.feat[node]
        inner = f >= 0
        if not inner.any():
            break
        xi = x[np.arange(n), np.maximum(f, 0)]
        go_left = xi <= tree.thresh[node]
        nxt = np.where(go_left, tree.left[node], tree.right[node])
        node = np.where(inner, nxt, node)
    return tree.value[node]
