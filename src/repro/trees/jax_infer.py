"""Tree-ensemble inference in JAX: level-wise gather descent.

LightGBM-style additive forests become five stacked arrays; prediction
is ``max_depth`` rounds of vectorised child selection — no
data-dependent control flow, so the ensemble runs *inside* the jitted
A-kNN search loop (DESIGN §2).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TreeEnsemble:
    feat: jnp.ndarray    # (T, M) int32 split feature, -1 at leaves
    thresh: jnp.ndarray  # (T, M) f32 split threshold
    left: jnp.ndarray    # (T, M) int32 child if x[f] <= thr (self at leaf)
    right: jnp.ndarray   # (T, M) int32
    value: jnp.ndarray   # (T, M) f32 leaf value (lr folded in), 0 inner
    base: jnp.ndarray    # () f32 initial prediction
    max_depth: int       # static

    def tree_flatten(self):
        return ((self.feat, self.thresh, self.left, self.right, self.value,
                 self.base), self.max_depth)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux)

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]


def predict_margin(ens: TreeEnsemble, x: jnp.ndarray) -> jnp.ndarray:
    """(B, F) -> (B,) raw margin (sum of leaf values + base)."""
    t, m = ens.feat.shape
    b = x.shape[0]
    flat_feat = ens.feat.reshape(-1)
    flat_thr = ens.thresh.reshape(-1)
    flat_l = ens.left.reshape(-1)
    flat_r = ens.right.reshape(-1)
    flat_v = ens.value.reshape(-1)
    toff = (jnp.arange(t, dtype=jnp.int32) * m)[None, :]        # (1, T)
    node = jnp.zeros((b, t), jnp.int32)

    def step(node, _):
        gidx = toff + node                                       # (B, T)
        f = jnp.take(flat_feat, gidx)
        thr = jnp.take(flat_thr, gidx)
        xv = jnp.take_along_axis(x, jnp.maximum(f, 0), axis=1)
        go_left = xv <= thr
        nxt = jnp.where(go_left, jnp.take(flat_l, gidx),
                        jnp.take(flat_r, gidx))
        node = jnp.where(f >= 0, nxt, node)                      # leaves stay
        return node, None

    node, _ = jax.lax.scan(step, node, None, length=ens.max_depth)
    vals = jnp.take(flat_v, toff + node)
    return jnp.sum(vals, axis=1) + ens.base


def predict_proba(ens: TreeEnsemble, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.sigmoid(predict_margin(ens, x))


def from_numpy_forest(forest, max_depth: int) -> TreeEnsemble:
    """Pack ``repro.trees.gbdt.Forest`` into stacked device arrays."""
    m = max(t.feat.shape[0] for t in forest.trees)
    t = len(forest.trees)

    def pad(a, fill, dtype):
        out = np.full((t, m), fill, dtype)
        for i, tree in enumerate(forest.trees):
            arr = getattr(tree, a)
            out[i, : arr.shape[0]] = arr
        return out

    # leaves self-loop so extra descent steps are no-ops
    left = pad("left", 0, np.int32)
    right = pad("right", 0, np.int32)
    feat = pad("feat", -1, np.int32)
    for i, tree in enumerate(forest.trees):
        leaves = np.nonzero(tree.feat == -1)[0]
        left[i, leaves] = leaves
        right[i, leaves] = leaves
    return TreeEnsemble(
        jnp.asarray(feat), jnp.asarray(pad("thresh", 0.0, np.float32)),
        jnp.asarray(left), jnp.asarray(right),
        jnp.asarray(pad("value", 0.0, np.float32)),
        jnp.asarray(np.float32(forest.base)), max_depth)
