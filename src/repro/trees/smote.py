"""SMOTE (Chawla et al., JAIR'02) — minority-class oversampling used to
rebalance the Exit/Continue classifier training set (paper §2)."""
from __future__ import annotations

from typing import Tuple

import numpy as np


def smote(x: np.ndarray, y: np.ndarray, *, k: int = 5, seed: int = 0,
          target_ratio: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    """Oversample the minority class with k-NN interpolation.

    target_ratio: desired minority/majority count ratio after sampling.
    Returns augmented (x, y); original rows come first.
    """
    x = np.asarray(x, np.float32)
    y = np.asarray(y)
    rng = np.random.default_rng(seed)
    classes, counts = np.unique(y, return_counts=True)
    if len(classes) == 1:
        return x, y          # degenerate split: nothing to rebalance
    if len(classes) != 2:
        raise ValueError("smote expects binary labels")
    minority = classes[np.argmin(counts)]
    majority_n = counts.max()
    minority_idx = np.nonzero(y == minority)[0]
    need = int(target_ratio * majority_n) - minority_idx.size
    if need <= 0 or minority_idx.size < 2:
        return x, y
    pts = x[minority_idx]
    kk = min(k, pts.shape[0] - 1)
    # brute-force k-NN within the minority class (blocked for memory)
    nn = np.empty((pts.shape[0], kk), np.int64)
    block = 1024
    sq = (pts ** 2).sum(1)
    for s in range(0, pts.shape[0], block):
        e = min(s + block, pts.shape[0])
        d2 = sq[s:e, None] - 2.0 * pts[s:e] @ pts.T + sq[None, :]
        d2[np.arange(e - s), np.arange(s, e)] = np.inf
        nn[s:e] = np.argpartition(d2, kk, axis=1)[:, :kk]
    src = rng.integers(0, pts.shape[0], need)
    nbr = nn[src, rng.integers(0, kk, need)]
    u = rng.random((need, 1)).astype(np.float32)
    synth = pts[src] + u * (pts[nbr] - pts[src])
    xa = np.concatenate([x, synth], 0)
    ya = np.concatenate([y, np.full(need, minority, y.dtype)])
    return xa, ya
